//! Steady-state L-step benchmark (`cargo bench --bench l_step_bench`):
//! the measurement behind the data-parallel, workspace-backed train step.
//!
//! Three claims, all recorded in `BENCH_l_step.json`:
//!
//! 1. **Allocation-free L step.** With a persistent `GradWorkspace`
//!    (owned by `TrainDriver`), the steady-state train step — forward,
//!    softmax/CE, sharded backward, gradient tree-reduce, fused
//!    penalty + Nesterov update — performs **zero** heap allocations at
//!    `threads = 1` (counted by a wrapping global allocator; parallel
//!    runs pay only the scoped-thread spawn, no per-step buffers).
//! 2. **Thread-count invariance.** The shard layout is a function of the
//!    batch size only and gradient shards are tree-reduced in a fixed
//!    pair order, so parameters and momenta after any number of steps are
//!    bit-identical for threads = 1, 2, 4.
//! 3. **Sharded speedup.** An L epoch (fixed step count) at 4 threads vs
//!    the serial path on the same model; full runs assert > 1.5x, quick
//!    (CI smoke) runs only record the ratio since shared runners vary in
//!    core count and scheduling noise.
//! 4. **Pack-cache steady state.** The generation-stamped weight-pack
//!    cache packs each weight panel exactly once per train step
//!    (`2·nl − 1` misses/step: a forward panel per layer plus a
//!    transposed backward panel for every layer past the first), and
//!    every per-shard GEMM after that is a cache hit — packing cost no
//!    longer scales with the shard count.
//! 5. **Compressed L epoch.** Training *through* the compressed kernels
//!    (`--l-mode compressed`: CSR values at a fixed 5% pattern on the big
//!    layer, 16-center codebooks elsewhere, on lenet300) vs the dense
//!    penalized epoch it replaces.  Full runs assert the
//!    `l_step_compressed_speedup` ratio ≥ 1.5x; quick runs record it and
//!    print the per-layer train-kernel FLOPs table.
//!
//! Bench config: lenet300-wide (784-500-300-10, 545k weights), batch 128
//! (4 gradient shards), penalty active on every layer so the fused
//! penalty/update pass is on the measured path.  `LCC_BENCH_QUICK=1`
//! bounds the iteration budget for CI smoke runs.

use lc::bench::{alloc_counts, write_bench_json, Bencher, CountingAlloc, Record};
use lc::compress::prune::ConstraintL0;
use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::compress::{CContext, Theta};
use lc::infer::train::CompressedTrainState;
use lc::linalg::gemm;
use lc::models::{lookup, ParamState};
use lc::runtime::trainer::TrainDriver;
use lc::tensor::Matrix;
use lc::util::rng::Xoshiro256;

// counting allocator (shared impl in lc::bench; the attribute must live in
// the binary)
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// --- bench scenario --------------------------------------------------------

struct Scenario {
    spec: lc::models::ModelSpec,
    state0: ParamState,
    x: Vec<f32>,
    y: Vec<i32>,
    deltas: Vec<Matrix>,
    lambdas: Vec<Matrix>,
    mu: Vec<f32>,
}

fn scenario() -> Scenario {
    let spec = lookup("lenet300-wide").unwrap();
    let state0 = ParamState::init(&spec, 42);
    let mut rng = Xoshiro256::new(7);
    let mut x = vec![0.0f32; spec.batch * spec.widths[0]];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let classes = *spec.widths.last().unwrap();
    let y: Vec<i32> = (0..spec.batch).map(|_| rng.below(classes) as i32).collect();
    // penalty active on every layer: the fused penalty/update pass is on
    // the measured path, like a real covered-layer L step
    let deltas: Vec<Matrix> = (0..spec.n_layers())
        .map(|l| {
            let (m, n) = spec.layer_shape(l);
            let mut d = Matrix::zeros(m, n);
            rng.fill_normal(&mut d.data, 0.0, 0.05);
            d
        })
        .collect();
    let lambdas: Vec<Matrix> = (0..spec.n_layers())
        .map(|l| {
            let (m, n) = spec.layer_shape(l);
            let mut d = Matrix::zeros(m, n);
            rng.fill_normal(&mut d.data, 0.0, 0.01);
            d
        })
        .collect();
    let mu = vec![1e-2f32; spec.n_layers()];
    Scenario { spec, state0, x, y, deltas, lambdas, mu }
}

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let sc = scenario();
    let n_weights = sc.spec.n_weights();
    let mut records: Vec<Record> = Vec::new();

    // --- 1-vs-N-thread bit equality ----------------------------------------
    {
        let steps = 3usize;
        let run = |threads: usize| {
            let driver = TrainDriver::native_for_spec(&sc.spec, threads);
            let mut s = sc.state0.clone();
            for _ in 0..steps {
                driver
                    .step(&mut s, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                    .unwrap();
            }
            s
        };
        let want = run(1);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for threads in [2usize, 4] {
            let got = run(threads);
            for l in 0..sc.spec.n_layers() {
                assert_eq!(
                    bits(&got.weights[l].data),
                    bits(&want.weights[l].data),
                    "weights[{l}] not bit-identical at threads={threads}"
                );
                assert_eq!(
                    bits(&got.w_momenta[l].data),
                    bits(&want.w_momenta[l].data),
                    "momenta[{l}] not bit-identical at threads={threads}"
                );
                assert_eq!(bits(&got.biases[l]), bits(&want.biases[l]), "biases[{l}]");
            }
        }
        println!("bit equality over {steps} steps: threads 1 == 2 == 4");
        records.push(Record {
            bench: "bit_equality".into(),
            fields: vec![
                ("steps".into(), steps.to_string()),
                ("threads_compared".into(), "\"1,2,4\"".into()),
                ("bit_identical".into(), "true".into()),
            ],
        });
    }

    // --- allocation audit of the steady-state L step (threads = 1) ---------
    {
        let driver = TrainDriver::native_for_spec(&sc.spec, 1);
        let mut state = sc.state0.clone();
        // warm-up: first step shapes the workspace, second proves reuse
        for _ in 0..2 {
            driver
                .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                .unwrap();
        }
        let iters = if quick { 10u64 } else { 50 };
        let (a0, b0) = alloc_counts();
        for _ in 0..iters {
            std::hint::black_box(
                driver
                    .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                    .unwrap(),
            );
        }
        let (a1, b1) = alloc_counts();
        let allocs_per_step = (a1 - a0) as f64 / iters as f64;
        let bytes_per_step = (b1 - b0) as f64 / iters as f64;
        println!(
            "L step steady state ({iters} steps, threads=1): {allocs_per_step:.2} allocs/step, \
             {bytes_per_step:.1} bytes/step"
        );
        assert_eq!(a1 - a0, 0, "steady-state L step must be allocation-free at threads=1");
        records.push(Record {
            bench: "l_step_allocs".into(),
            fields: vec![
                ("iters".into(), iters.to_string()),
                ("threads".into(), "1".into()),
                ("allocs_per_step".into(), format!("{allocs_per_step:.3}")),
                ("bytes_per_step".into(), format!("{bytes_per_step:.1}")),
                ("allocation_free".into(), (a1 - a0 == 0).to_string()),
            ],
        });
    }

    // --- pack-cache steady state: one pack per weight panel per step --------
    {
        let driver = TrainDriver::native_for_spec(&sc.spec, 4);
        let mut state = sc.state0.clone();
        // warm-up: shapes the workspace and fills the cache
        for _ in 0..2 {
            driver
                .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                .unwrap();
        }
        let steps = 10u64;
        let (h0, m0) = gemm::pack_cache_counters();
        for _ in 0..steps {
            driver
                .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                .unwrap();
        }
        let (h1, m1) = gemm::pack_cache_counters();
        let (hits, misses) = (h1 - h0, m1 - m0);
        let nl = sc.spec.n_layers() as u64;
        println!(
            "pack cache over {steps} steps: {misses} misses ({} per step), {hits} hits",
            misses / steps
        );
        // the optimizer bumps the weight generation every step, so steady
        // state is exactly one (re)pack per panel per step: nl forward
        // panels + (nl − 1) transposed backward panels
        assert_eq!(
            misses,
            steps * (2 * nl - 1),
            "expected exactly 2·nl−1 = {} pack-cache misses per step",
            2 * nl - 1
        );
        assert!(hits > misses, "per-shard GEMMs should hit the cache more often than it repacks");
        records.push(Record {
            bench: "l_step_pack_cache".into(),
            fields: vec![
                ("steps".into(), steps.to_string()),
                ("n_layers".into(), nl.to_string()),
                ("misses".into(), misses.to_string()),
                ("hits".into(), hits.to_string()),
                ("misses_per_step".into(), (misses / steps).to_string()),
            ],
        });
    }

    // --- L-epoch wall time: serial vs sharded -------------------------------
    {
        let epoch_steps = if quick { 6usize } else { 20 };
        Bencher::header(&format!(
            "L epoch ({epoch_steps} steps, batch {}, {n_weights} weights)",
            sc.spec.batch
        ));
        let mut times_ms = Vec::new();
        for &threads in &[1usize, 2, 4] {
            let driver = TrainDriver::native_for_spec(&sc.spec, threads);
            let mut state = sc.state0.clone();
            // warm the workspace outside the measured region
            driver
                .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                .unwrap();
            let ms = b
                .bench(&format!("L epoch t={threads}"), || {
                    for _ in 0..epoch_steps {
                        driver
                            .step(&mut state, &sc.x, &sc.y, &sc.deltas, &sc.lambdas, &sc.mu, 0.05)
                            .unwrap();
                    }
                })
                .mean_ns
                / 1e6;
            times_ms.push((threads, ms));
        }
        let serial_ms = times_ms[0].1;
        let sharded_ms = times_ms.last().unwrap().1;
        let speedup = serial_ms / sharded_ms.max(1e-12);
        let samples_per_sec =
            (epoch_steps * sc.spec.batch) as f64 / (sharded_ms / 1e3).max(1e-12);
        println!(
            "speedup: {speedup:.2}x at 4 threads (serial {serial_ms:.2}ms -> {sharded_ms:.2}ms, \
             {:.1}k samples/s)",
            samples_per_sec / 1e3
        );
        // full runs assert the acceptance target; quick (CI smoke) runs
        // only record the ratio — shared runners vary in core count and
        // scheduling noise, and a wall-clock gate there would flake
        if !quick {
            assert!(
                speedup >= 1.5,
                "sharded L epoch speedup {speedup:.2}x below the 1.5x target at 4 threads"
            );
        }
        for (threads, ms) in &times_ms {
            records.push(Record {
                bench: "l_epoch".into(),
                fields: vec![
                    ("config".into(), "\"lenet300-wide batch=128 penalty-on\"".into()),
                    ("threads".into(), threads.to_string()),
                    ("steps".into(), epoch_steps.to_string()),
                    ("n_weights".into(), n_weights.to_string()),
                    ("epoch_ms".into(), format!("{ms:.3}")),
                ],
            });
        }
        records.push(Record {
            bench: "l_epoch_speedup".into(),
            fields: vec![
                ("threads".into(), "4".into()),
                ("serial_ms".into(), format!("{serial_ms:.3}")),
                ("sharded_ms".into(), format!("{sharded_ms:.3}")),
                ("speedup".into(), format!("{speedup:.3}")),
                ("samples_per_sec".into(), format!("{samples_per_sec:.1}")),
            ],
        });
    }

    // --- compressed vs dense L epoch (lenet300) -----------------------------
    {
        let spec = lookup("lenet300").unwrap();
        let state0 = ParamState::init(&spec, 42);
        let mut rng = Xoshiro256::new(11);
        let mut x = vec![0.0f32; spec.batch * spec.widths[0]];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let classes = *spec.widths.last().unwrap();
        let y: Vec<i32> = (0..spec.batch).map(|_| rng.below(classes) as i32).collect();
        let deltas: Vec<Matrix> = (0..spec.n_layers())
            .map(|l| {
                let (m, n) = spec.layer_shape(l);
                let mut d = Matrix::zeros(m, n);
                rng.fill_normal(&mut d.data, 0.0, 0.05);
                d
            })
            .collect();
        let lambdas: Vec<Matrix> = (0..spec.n_layers())
            .map(|l| {
                let (m, n) = spec.layer_shape(l);
                let mut d = Matrix::zeros(m, n);
                rng.fill_normal(&mut d.data, 0.0, 0.01);
                d
            })
            .collect();
        let mu = vec![1e-2f32; spec.n_layers()];

        // the acceptance scenario: 5%-sparse CSR on the big input layer,
        // 16-center codebooks on the rest
        let (m0, n0) = spec.layer_shape(0);
        let tasks = TaskSet::new(vec![
            TaskSpec {
                name: "p0".into(),
                layers: vec![0],
                view: View::Vector,
                compression: Box::new(ConstraintL0 { kappa: m0 * n0 / 20 }),
            },
            TaskSpec {
                name: "q12".into(),
                layers: vec![1, 2],
                view: View::Vector,
                compression: Box::new(AdaptiveQuant::new(16)),
            },
        ]);
        let ctx = CContext::default();
        let thetas: Vec<Theta> = tasks
            .tasks
            .iter()
            .map(|t| t.compression.compress(&t.gather(&state0.weights), &ctx))
            .collect();
        let refs: Vec<&Theta> = thetas.iter().collect();
        let cs0 = CompressedTrainState::plan(&spec, &tasks, &refs);
        assert_eq!(cs0.kernel_name(0), "csr");
        assert_eq!(cs0.kernel_name(1), "codebook");
        assert_eq!(cs0.kernel_name(2), "codebook");

        // per-layer train-kernel FLOPs table (forward MACs per example)
        println!();
        println!("per-layer train kernels (lenet300, prune 5% + quant k=16):");
        println!("{:<7} {:<10} {:>12} {:>12} {:>8}", "layer", "kernel", "dense MACs", "kernel MACs", "ratio");
        for l in 0..spec.n_layers() {
            let (m, n) = spec.layer_shape(l);
            let dense = (m * n) as u64;
            let kern = cs0.train_flops_per_example(&spec, l);
            println!(
                "{:<7} {:<10} {:>12} {:>12} {:>7.1}x",
                l,
                cs0.kernel_name(l),
                dense,
                kern,
                dense as f64 / kern.max(1) as f64
            );
        }

        let epoch_steps = if quick { 6usize } else { 20 };
        Bencher::header(&format!(
            "compressed vs dense L epoch (lenet300, {epoch_steps} steps, batch {}, 4 threads)",
            spec.batch
        ));
        let dense_ms = {
            let driver = TrainDriver::native_for_spec(&spec, 4);
            let mut state = state0.clone();
            driver.step(&mut state, &x, &y, &deltas, &lambdas, &mu, 0.05).unwrap();
            b.bench("L epoch dense", || {
                for _ in 0..epoch_steps {
                    driver.step(&mut state, &x, &y, &deltas, &lambdas, &mu, 0.05).unwrap();
                }
            })
            .mean_ns
                / 1e6
        };
        let compressed_ms = {
            let driver = TrainDriver::native_for_spec(&spec, 4);
            let mut state = state0.clone();
            let mut cs = cs0.clone();
            driver
                .step_compressed(&mut state, &mut cs, &x, &y, &deltas, &lambdas, &mu, 0.05)
                .unwrap();
            b.bench("L epoch compressed", || {
                for _ in 0..epoch_steps {
                    driver
                        .step_compressed(&mut state, &mut cs, &x, &y, &deltas, &lambdas, &mu, 0.05)
                        .unwrap();
                }
            })
            .mean_ns
                / 1e6
        };
        let speedup = dense_ms / compressed_ms.max(1e-12);
        println!(
            "compressed-mode speedup: {speedup:.2}x (dense {dense_ms:.2}ms -> compressed \
             {compressed_ms:.2}ms per epoch)"
        );
        // same gating policy as the sharded-speedup claim: full runs
        // enforce the acceptance target, CI smoke only records the ratio
        if !quick {
            assert!(
                speedup >= 1.5,
                "compressed L epoch speedup {speedup:.2}x below the 1.5x target"
            );
        }
        records.push(Record {
            bench: "l_step_compressed_speedup".into(),
            fields: vec![
                ("config".into(), "\"lenet300 prune5%+quant16 batch default\"".into()),
                ("threads".into(), "4".into()),
                ("steps".into(), epoch_steps.to_string()),
                ("dense_ms".into(), format!("{dense_ms:.3}")),
                ("compressed_ms".into(), format!("{compressed_ms:.3}")),
                ("speedup".into(), format!("{speedup:.3}")),
            ],
        });
    }

    // --- BENCH_l_step.json --------------------------------------------------
    write_bench_json("BENCH_l_step.json", &records);
}
