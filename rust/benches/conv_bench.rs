//! Convolution + streaming benchmarks (`cargo bench --bench conv_bench`):
//! the measurements behind the layer-op IR and the chunked data stream.
//!
//! Four claims, all recorded in `BENCH_conv.json`:
//!
//! 1. **im2col-GEMM throughput.** Each lenet5-conv conv layer lowered onto
//!    the packed GEMM microkernel, reported in GFLOP/s at the registry
//!    batch size.
//! 2. **Streaming loader throughput.** Rows/sec through
//!    `data::stream::for_each_batch`, with the observed chunk-residency
//!    high-water mark asserted ≤ 2 (the double-buffer cap).
//! 3. **Allocation-free conv L step.** The steady-state train step of the
//!    lenet5-conv registry entry — im2col forward, col2im backward, shard
//!    tree-reduce, fused penalty update — performs **zero** heap
//!    allocations at `threads = 1` once the workspace is warm.
//! 4. **Streaming LC e2e on a >10M-weight conv model.** vgg-small
//!    (10.77M weights) runs one full LC step — streamed L epoch, C step,
//!    multipliers, final evals — with training data residency capped at
//!    two chunks, bit-identical across thread counts, and the saved LCCZ
//!    checkpoint's compressed execution passes the infer equivalence gate
//!    against the dense-Δ(Θ) eval.
//!
//! `LCC_BENCH_QUICK=1` bounds iteration counts and model scale for CI
//! smoke runs.

use std::time::Instant;

use lc::bench::{alloc_counts, write_bench_json, Bencher, CountingAlloc, Record};
use lc::compress::prune::ConstraintL0;
use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::data::stream::{self, StreamConfig};
use lc::data::synth;
use lc::lc::schedule::{LrSchedule, MuSchedule};
use lc::lc::{LcAlgorithm, LcConfig};
use lc::linalg::conv;
use lc::models::checkpoint::{load_compressed, save_compressed, CompressedCheckpoint};
use lc::models::{lookup, OpKind, ParamState};
use lc::runtime::trainer::{EvalDriver, TrainDriver};
use lc::runtime::Runtime;
use lc::tensor::Matrix;
use lc::util::rng::Xoshiro256;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut records: Vec<Record> = Vec::new();

    // --- 1. im2col-GEMM GFLOP/s at lenet5-conv shapes -----------------------
    {
        let spec = lookup("lenet5-conv").unwrap();
        Bencher::header(&format!("im2col + packed GEMM (batch {})", spec.batch));
        let mut rng = Xoshiro256::new(3);
        for (l, op) in spec.ops.iter().enumerate() {
            let OpKind::Conv2d(cs) = op.kind else { continue };
            let mut x = vec![0.0f32; spec.batch * cs.in_elems()];
            rng.fill_normal(&mut x, 0.0, 1.0);
            let mut w = Matrix::zeros(cs.patch_len(), cs.out_ch);
            rng.fill_normal(&mut w.data, 0.0, 0.1);
            let mut col = Matrix::zeros(0, 0);
            let stats = b.bench(&format!("layer {l}: {}", op.describe()), || {
                conv::im2col(&x, spec.batch, &cs, &mut col);
                std::hint::black_box(col.matmul_par(&w, 4));
            });
            let macs = (spec.batch * cs.spatial() * cs.patch_len() * cs.out_ch) as f64;
            let gflops = 2.0 * macs / stats.mean_ns;
            println!("    -> {gflops:.2} GFLOP/s");
            records.push(Record {
                bench: "im2col_gemm".into(),
                fields: vec![
                    ("op".into(), format!("{:?}", op.describe())),
                    ("batch".into(), spec.batch.to_string()),
                    ("macs".into(), format!("{macs:.0}")),
                    ("mean_ms".into(), format!("{:.3}", stats.mean_ns / 1e6)),
                    ("gflops".into(), format!("{gflops:.3}")),
                ],
            });
        }
    }

    // --- 2. streaming loader rows/sec + residency cap -----------------------
    {
        let total = if quick { 2048usize } else { 8192 };
        let cfg = StreamConfig { total, chunk: 1024, seed: 17 };
        let batch = 128usize;
        let mut rng = Xoshiro256::new(5);
        let mut checksum = 0.0f64;
        let t0 = Instant::now();
        let stats = stream::for_each_batch(&cfg, batch, &mut rng, |x, y| {
            // touch the data so synthesis can't be optimized away
            checksum += x[0] as f64 + y[0] as f64;
        })
        .expect("streaming bench pass failed");
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(checksum);
        assert!(
            stats.max_resident_chunks <= 2,
            "streaming loader exceeded the two-chunk residency cap: {}",
            stats.max_resident_chunks
        );
        let rows_per_sec = stats.rows as f64 / secs.max(1e-9);
        println!(
            "streaming loader: {} rows in {:.1} chunks, {:.1}k rows/s, max resident {} chunks",
            stats.rows,
            stats.chunks as f64,
            rows_per_sec / 1e3,
            stats.max_resident_chunks
        );
        records.push(Record {
            bench: "stream_loader".into(),
            fields: vec![
                ("total_rows".into(), total.to_string()),
                ("chunk_rows".into(), cfg.chunk.to_string()),
                ("batch".into(), batch.to_string()),
                ("rows_consumed".into(), stats.rows.to_string()),
                ("rows_per_sec".into(), format!("{rows_per_sec:.1}")),
                ("max_resident_chunks".into(), stats.max_resident_chunks.to_string()),
            ],
        });
    }

    // --- 3. allocation audit of the steady-state conv L step ----------------
    {
        let spec = lookup("lenet5-conv").unwrap();
        let driver = TrainDriver::native_for_spec(&spec, 1);
        let mut state = ParamState::init(&spec, 42);
        let mut rng = Xoshiro256::new(7);
        let mut x = vec![0.0f32; spec.batch * spec.widths[0]];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let classes = *spec.widths.last().unwrap();
        let y: Vec<i32> = (0..spec.batch).map(|_| rng.below(classes) as i32).collect();
        let deltas: Vec<Matrix> = (0..spec.n_layers())
            .map(|l| {
                let (m, n) = spec.layer_shape(l);
                let mut d = Matrix::zeros(m, n);
                rng.fill_normal(&mut d.data, 0.0, 0.05);
                d
            })
            .collect();
        let lambdas: Vec<Matrix> = (0..spec.n_layers())
            .map(|l| {
                let (m, n) = spec.layer_shape(l);
                Matrix::zeros(m, n)
            })
            .collect();
        let mu = vec![1e-2f32; spec.n_layers()];
        // warm-up: first step shapes the workspace (incl. per-shard im2col
        // scratch), second proves reuse
        for _ in 0..2 {
            driver.step(&mut state, &x, &y, &deltas, &lambdas, &mu, 0.05).unwrap();
        }
        let iters = if quick { 5u64 } else { 25 };
        let (a0, b0) = alloc_counts();
        for _ in 0..iters {
            std::hint::black_box(
                driver.step(&mut state, &x, &y, &deltas, &lambdas, &mu, 0.05).unwrap(),
            );
        }
        let (a1, b1) = alloc_counts();
        let allocs_per_step = (a1 - a0) as f64 / iters as f64;
        println!(
            "conv L step steady state ({iters} steps, threads=1): {allocs_per_step:.2} \
             allocs/step, {:.1} bytes/step",
            (b1 - b0) as f64 / iters as f64
        );
        assert_eq!(a1 - a0, 0, "steady-state conv L step must be allocation-free at threads=1");
        records.push(Record {
            bench: "conv_l_step_allocs".into(),
            fields: vec![
                ("model".into(), "\"lenet5-conv\"".into()),
                ("iters".into(), iters.to_string()),
                ("threads".into(), "1".into()),
                ("allocs_per_step".into(), format!("{allocs_per_step:.3}")),
                ("allocation_free".into(), (a1 - a0 == 0).to_string()),
            ],
        });
    }

    // --- 4. vgg-small streaming LC step + infer equivalence gate ------------
    {
        let spec = lookup("vgg-small").unwrap();
        assert!(spec.n_weights() > 10_000_000, "vgg-small must exceed 10M weights");
        // two chunks of 128 = 4 batches of 64 per L epoch; never more than
        // two chunks (≈ 2·128·784 floats of training data) resident
        let total = if quick { 128usize } else { 256 };
        let train_stream = StreamConfig { total, chunk: 128, seed: 11 };
        let test = synth::generate(128, 12, 4);
        let tasks = || {
            TaskSet::new(vec![
                TaskSpec {
                    name: "quant-convs".into(),
                    layers: vec![0, 1, 2],
                    view: View::Vector,
                    compression: Box::new(AdaptiveQuant::new(6)),
                },
                TaskSpec {
                    name: "prune-fc".into(),
                    layers: vec![3],
                    view: View::Vector,
                    compression: Box::new(ConstraintL0 { kappa: 500_000 }),
                },
            ])
        };
        let run = |threads: usize| {
            let mut rt = Runtime::native_with_threads(threads);
            let cfg = LcConfig {
                mu: MuSchedule { mu0: 1e-3, growth: 1.5, steps: 1 },
                lr: LrSchedule { lr0: 0.02, decay: 0.98 },
                epochs_per_step: 1,
                first_step_epochs: None,
                use_al: true,
                seed: 23,
                threads,
                eval_every: 0,
                quiet: true,
                l_mode: lc::lc::LMode::Dense,
                ..Default::default()
            };
            let alg = LcAlgorithm::new(&mut rt, spec.clone(), tasks(), cfg).unwrap();
            let t0 = Instant::now();
            let out = alg.run_stream(ParamState::init(&spec, 1), &train_stream, &test).unwrap();
            (out, t0.elapsed().as_secs_f64())
        };

        Bencher::header(&format!(
            "vgg-small streaming LC step ({} weights, {} streamed rows)",
            spec.n_weights(),
            total
        ));
        let (want, secs1) = run(1);
        println!("threads=1: {secs1:.2}s, final test err {:.2}%", want.final_test.error * 100.0);
        let thread_set: &[usize] = if quick { &[2] } else { &[2, 4] };
        for &threads in thread_set {
            let (got, secs) = run(threads);
            for l in 0..spec.n_layers() {
                assert_eq!(
                    bits(&got.compressed_state.weights[l].data),
                    bits(&want.compressed_state.weights[l].data),
                    "streamed compressed weights[{l}] diverge at threads={threads}"
                );
            }
            println!("threads={threads}: {secs:.2}s, bit-identical to threads=1");
        }

        // infer equivalence gate: LCCZ roundtrip, compressed execution vs
        // the dense-Δ(Θ) eval (same gate `lcc infer --expect` applies)
        let ck = CompressedCheckpoint::from_lc(
            &spec,
            &tasks(),
            &want.thetas,
            &want.compressed_state,
        );
        let path = std::env::temp_dir().join("conv_bench_vgg_small.lccz");
        save_compressed(&ck, &path).unwrap();
        let model = load_compressed(&path).unwrap().to_model(spec.eval_batch).unwrap();
        model.validate().unwrap();
        let _ = std::fs::remove_file(&path);
        let eval = EvalDriver::native_for_spec(&spec, 4);
        let dense = eval.eval(&want.compressed_state, &test).unwrap();
        let compressed = eval.eval_compressed(&model, &test).unwrap();
        assert_eq!(
            dense.error, compressed.error,
            "compressed execution must reproduce dense-Δ(Θ) argmax decisions"
        );
        assert!(
            (dense.mean_loss - compressed.mean_loss).abs()
                <= 1e-5 * dense.mean_loss.abs().max(1.0),
            "compressed loss {} vs dense {}",
            compressed.mean_loss,
            dense.mean_loss
        );
        println!(
            "infer gate: compressed exec == dense Δ(Θ) (err {:.2}%, {} -> {} MACs/example)",
            compressed.error * 100.0,
            spec.flops_dense(),
            model.flops_per_example()
        );
        records.push(Record {
            bench: "vgg_small_stream_lc".into(),
            fields: vec![
                ("model".into(), "\"vgg-small\"".into()),
                ("n_weights".into(), spec.n_weights().to_string()),
                ("streamed_rows".into(), total.to_string()),
                ("chunk_rows".into(), train_stream.chunk.to_string()),
                ("step_secs_t1".into(), format!("{secs1:.3}")),
                ("bit_identical".into(), "true".into()),
                ("infer_gate".into(), "true".into()),
                ("final_test_err".into(), format!("{:.4}", want.final_test.error)),
                ("macs_per_example".into(), model.flops_per_example().to_string()),
            ],
        });
    }

    write_bench_json("BENCH_conv.json", &records);
}
