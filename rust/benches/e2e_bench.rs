//! End-to-end LC benchmarks: a full LC step (L epochs + C step +
//! multipliers) vs a plain reference-training epoch — the measurement
//! behind the paper's headline claim that *compression runtime is
//! comparable to training the reference*.
//!
//! `cargo bench --bench e2e_bench`.  Runs on whichever backend the runtime
//! auto-selects (native needs no artifacts).

use lc::bench::Bencher;
use lc::compress::prune::ConstraintL0;
use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::harness::{Env, Scale};
use lc::lc::schedule::{LrSchedule, MuSchedule};
use lc::lc::{LcAlgorithm, LcConfig};
use lc::models::lookup;

fn main() {
    let scale = Scale { n_train: 2048, n_test: 512, reference_epochs: 2, ..Default::default() };
    let mut env = Env::new(scale).expect("env");
    println!("backend: {}", env.rt.backend_name());
    let spec = lookup("lenet300").unwrap();
    let mut b = Bencher::default();
    b.budget = std::time::Duration::from_secs(20);
    b.max_iters = 8;

    let one_step_cfg = |tasks_quiet: bool| LcConfig {
        mu: MuSchedule { mu0: 1e-3, growth: 1.5, steps: 1 },
        lr: LrSchedule { lr0: 0.05, decay: 0.98 },
        epochs_per_step: 1,
        first_step_epochs: None,
        use_al: true,
        seed: 42,
        threads: 4,
        eval_every: 0,
        quiet: tasks_quiet,
        l_mode: lc::lc::LMode::Dense,
        ..Default::default()
    };

    Bencher::header("end-to-end: one LC step vs one reference epoch (lenet300, 2048 ex)");

    // reference epoch
    {
        let alg = LcAlgorithm::new(
            &mut env.rt,
            spec.clone(),
            TaskSet::new(vec![]),
            one_step_cfg(true),
        )
        .unwrap();
        let mut state = env.reference(&spec).unwrap();
        let data = env.train_data.clone();
        b.bench("reference training epoch", || {
            alg.train_reference(&mut state, &data, 1, &LrSchedule { lr0: 0.05, decay: 1.0 })
                .unwrap()
        });
    }

    // one full LC step (1 epoch L + C + multipliers) for three task mixes
    let mixes: Vec<(&str, fn(usize) -> TaskSet)> = vec![
        ("LC step: quantize-all k=2", |n| {
            let _ = n;
            TaskSet::new(vec![TaskSpec {
                name: "q".into(),
                layers: vec![0, 1, 2],
                view: View::Vector,
                compression: Box::new(AdaptiveQuant::new(2)),
            }])
        }),
        ("LC step: prune 5%", |n| {
            TaskSet::new(vec![TaskSpec {
                name: "p".into(),
                layers: vec![0, 1, 2],
                view: View::Vector,
                compression: Box::new(ConstraintL0 { kappa: n / 20 }),
            }])
        }),
    ];

    for (label, mk_tasks) in mixes {
        let n = spec.n_weights();
        let reference = env.reference(&spec).unwrap();
        let alg =
            LcAlgorithm::new(&mut env.rt, spec.clone(), mk_tasks(n), one_step_cfg(true)).unwrap();
        let train = env.train_data.clone();
        let test = env.test_data.clone();
        b.bench(label, || {
            alg.run(reference.clone(), &train, &test).unwrap()
        });
    }

    // paper headline ratio
    if b.results.len() >= 2 {
        let ref_epoch = b.results[0].mean_ns;
        println!();
        for s in &b.results[1..] {
            println!(
                "{}: {:.2}x one reference epoch (paper claim: comparable runtime; an LC\n\
                 step adds the C step + eval on top of its L-step epochs)",
                s.name,
                s.mean_ns / ref_epoch
            );
        }
    }
}
