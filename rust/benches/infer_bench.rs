//! Dense vs compressed execution per scheme (`cargo bench --bench
//! infer_bench`) — the measurement behind the compressed-execution
//! engine's claim that a 10x-FLOPs-ratio model really runs ~10x less
//! work per example instead of decompressing to a dense GEMM.
//!
//! For each scheme x compression-ratio point the harness builds a
//! lenet300-shaped model (784-300-100-10), materializes the equivalent
//! dense weights, verifies the two forwards agree within 1e-5 relative,
//! and times both paths on a fixed batch.  Results go to stdout and to
//! `BENCH_infer.json` (one record per scenario) so CI can track the perf
//! trajectory per PR.  `LCC_BENCH_QUICK=1` bounds the iteration budget
//! for smoke runs.

use std::io::Write;

use lc::bench::Bencher;
use lc::compress::Theta;
use lc::infer::{CompressedLayer, CompressedModel};
use lc::tensor::Matrix;
use lc::util::rng::Xoshiro256;

const WIDTHS: [usize; 4] = [784, 300, 100, 10];
const BATCH: usize = 512;
const THREADS: usize = 4;

struct Scenario {
    scheme: &'static str,
    config: String,
    /// Per-layer Θ (one single-layer task per weight matrix).
    thetas: Vec<Theta>,
}

fn rand_matrix(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    rng.fill_normal(&mut m.data, 0.0, 0.5);
    m
}

fn lowrank_theta(m: usize, n: usize, rank: usize, rng: &mut Xoshiro256) -> Theta {
    let u = rand_matrix(m, rank, rng);
    let v = rand_matrix(n, rank, rng);
    let s: Vec<f32> = (0..rank).map(|i| 1.0 + (rank - i) as f32 / rank as f32).collect();
    Theta::LowRank { u, s, v }
}

fn sparse_theta(m: usize, n: usize, keep_frac: f64, rng: &mut Xoshiro256) -> Theta {
    let total = m * n;
    let keep = ((total as f64 * keep_frac) as usize).max(1);
    let mut idx = rng.sample_indices(total, keep);
    idx.sort_unstable();
    let values: Vec<f32> = idx.iter().map(|_| rng.normal_f32(0.0, 0.5)).collect();
    Theta::Sparse { len: total, indices: idx.iter().map(|&i| i as u32).collect(), values }
}

fn quantized_theta(m: usize, n: usize, k: usize, rng: &mut Xoshiro256) -> Theta {
    let codebook: Vec<f32> = (0..k).map(|i| (i as f32 + 0.5) / k as f32 - 0.5).collect();
    let assignments: Vec<u32> = (0..m * n).map(|_| rng.below(k) as u32).collect();
    Theta::Quantized { codebook, assignments }
}

fn signs_theta(m: usize, n: usize, rng: &mut Xoshiro256) -> Theta {
    let values: Vec<i8> = (0..m * n).map(|_| rng.below(3) as i8 - 1).collect();
    Theta::Signs { scale: 0.25, values, ternary: true }
}

fn scenarios() -> Vec<Scenario> {
    let mut rng = Xoshiro256::new(2020);
    let shapes: Vec<(usize, usize)> =
        (0..WIDTHS.len() - 1).map(|l| (WIDTHS[l], WIDTHS[l + 1])).collect();
    let mut out = Vec::new();

    // low-rank: rank as a fraction of the min dimension (1/4 is the
    // acceptance point; smaller ranks show the trajectory)
    for denom in [4usize, 8, 16] {
        out.push(Scenario {
            scheme: "low_rank",
            config: format!("rank=min/{denom}"),
            thetas: shapes
                .iter()
                .map(|&(m, n)| lowrank_theta(m, n, (m.min(n) / denom).max(1), &mut rng))
                .collect(),
        });
    }
    // pruning: survivors as a fraction of the weights (10% = the 90%-pruned
    // acceptance point)
    for keep in [0.10f64, 0.05, 0.01] {
        out.push(Scenario {
            scheme: "sparse",
            config: format!("keep={:.0}%", keep * 100.0),
            thetas: shapes.iter().map(|&(m, n)| sparse_theta(m, n, keep, &mut rng)).collect(),
        });
    }
    // quantization: codebook sizes
    for k in [2usize, 16] {
        out.push(Scenario {
            scheme: "quantized",
            config: format!("k={k}"),
            thetas: shapes.iter().map(|&(m, n)| quantized_theta(m, n, k, &mut rng)).collect(),
        });
    }
    // ternarization
    out.push(Scenario {
        scheme: "signs",
        config: "ternary".into(),
        thetas: shapes.iter().map(|&(m, n)| signs_theta(m, n, &mut rng)).collect(),
    });
    // additive: the classic low-rank + sparse decomposition, where the
    // summed kernels stay far below dense cost
    out.push(Scenario {
        scheme: "additive",
        config: "lowrank min/8 + sparse 5%".into(),
        thetas: shapes
            .iter()
            .map(|&(m, n)| {
                Theta::Additive(vec![
                    lowrank_theta(m, n, (m.min(n) / 8).max(1), &mut rng),
                    sparse_theta(m, n, 0.05, &mut rng),
                ])
            })
            .collect(),
    });
    out
}

fn build_models(sc: &Scenario) -> (CompressedModel, CompressedModel) {
    let mut rng = Xoshiro256::new(7);
    let nl = WIDTHS.len() - 1;
    let biases: Vec<Vec<f32>> = (0..nl)
        .map(|l| (0..WIDTHS[l + 1]).map(|_| rng.normal_f32(0.0, 0.1)).collect())
        .collect();
    let compressed_layers: Vec<CompressedLayer> = sc
        .thetas
        .iter()
        .enumerate()
        .map(|(l, t)| CompressedLayer::from_theta(t, WIDTHS[l], WIDTHS[l + 1]))
        .collect();
    // the dense twin always runs the tiled dense GEMM (no auto-CSR): this
    // is exactly the decompress-then-matmul path being replaced
    let dense_layers: Vec<CompressedLayer> = sc
        .thetas
        .iter()
        .enumerate()
        .map(|(l, t)| {
            CompressedLayer::Dense(Matrix::from_vec(WIDTHS[l], WIDTHS[l + 1], t.decompress()))
        })
        .collect();
    let mk = |layers| CompressedModel {
        name: format!("{}-{}", sc.scheme, sc.config),
        ops: lc::models::mlp_ops(&WIDTHS),
        widths: WIDTHS.to_vec(),
        eval_batch: BATCH,
        layers,
        biases: biases.clone(),
    };
    (mk(compressed_layers), mk(dense_layers))
}

struct Record {
    scheme: &'static str,
    config: String,
    storage_ratio: f64,
    flops_ratio: f64,
    dense_ms: f64,
    compressed_ms: f64,
    max_rel_diff: f64,
}

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    if !quick {
        b.budget = std::time::Duration::from_secs(4);
    }

    let mut rng = Xoshiro256::new(1);
    let mut x = vec![0.0f32; BATCH * WIDTHS[0]];
    rng.fill_normal(&mut x, 0.0, 1.0);

    let dense_macs: u64 =
        (0..WIDTHS.len() - 1).map(|l| (WIDTHS[l] * WIDTHS[l + 1]) as u64).sum();
    let mut records: Vec<Record> = Vec::new();

    Bencher::header(&format!(
        "compressed vs dense execution (784-300-100-10, batch {BATCH}, {THREADS} threads)"
    ));

    for sc in scenarios() {
        let (comp, dense) = build_models(&sc);
        comp.validate().expect("compressed model");
        dense.validate().expect("dense model");

        // equivalence first: identical inputs, 1e-5 relative
        let zc = comp.forward(&x, BATCH, THREADS).expect("compressed forward");
        let zd = dense.forward(&x, BATCH, THREADS).expect("dense forward");
        let mut max_rel = 0.0f64;
        for (c, d) in zc.data.iter().zip(zd.data.iter()) {
            let rel = (c - d).abs() as f64 / (d.abs().max(1.0)) as f64;
            if rel > max_rel {
                max_rel = rel;
            }
        }
        assert!(
            max_rel <= 1e-5,
            "{} {}: compressed/dense outputs diverge (max rel {max_rel:.3e})",
            sc.scheme,
            sc.config
        );

        let label = format!("{} {}", sc.scheme, sc.config);
        let dense_ms =
            b.bench(&format!("{label:<28} dense"), || dense.forward(&x, BATCH, THREADS)).mean_ns
                / 1e6;
        let compressed_ms = b
            .bench(&format!("{label:<28} compressed"), || comp.forward(&x, BATCH, THREADS))
            .mean_ns
            / 1e6;

        let storage_bits: u64 = sc.thetas.iter().map(|t| t.storage_bits()).sum();
        records.push(Record {
            scheme: sc.scheme,
            config: sc.config.clone(),
            storage_ratio: (32 * dense_macs) as f64 / storage_bits.max(1) as f64,
            flops_ratio: dense_macs as f64 / comp.flops_per_example().max(1) as f64,
            dense_ms,
            compressed_ms,
            max_rel_diff: max_rel,
        });
    }

    println!("\n{:<34} {:>9} {:>9} {:>9} {:>10}", "scenario", "FLOPsx", "storagex", "wallx", "maxrel");
    for r in &records {
        println!(
            "{:<34} {:>8.1}x {:>8.1}x {:>8.2}x {:>10.2e}",
            format!("{} {}", r.scheme, r.config),
            r.flops_ratio,
            r.storage_ratio,
            r.dense_ms / r.compressed_ms.max(1e-12),
            r.max_rel_diff
        );
    }

    // BENCH_infer.json: the per-PR perf trajectory artifact
    let mut json = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"scheme\": \"{}\", \"config\": \"{}\", \"batch\": {BATCH}, \
             \"threads\": {THREADS}, \"flops_ratio\": {:.3}, \"storage_ratio\": {:.3}, \
             \"dense_ms\": {:.4}, \"compressed_ms\": {:.4}, \"speedup\": {:.3}, \
             \"max_rel_diff\": {:.3e}}}{}\n",
            r.scheme,
            r.config,
            r.flops_ratio,
            r.storage_ratio,
            r.dense_ms,
            r.compressed_ms,
            r.dense_ms / r.compressed_ms.max(1e-12),
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    let path = "BENCH_infer.json";
    let mut f = std::fs::File::create(path).expect("create BENCH_infer.json");
    f.write_all(json.as_bytes()).expect("write BENCH_infer.json");
    println!("\nwrote {path} ({} scenarios)", records.len());
}
