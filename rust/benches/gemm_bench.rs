//! GEMM microkernel + thread-pool benchmark
//! (`cargo bench --bench gemm_bench`).
//!
//! Three claims, all recorded in `BENCH_gemm.json`:
//!
//! 1. **Packed speedup.** The packed SIMD microkernel
//!    (`lc::linalg::gemm`) vs the scalar ikj triple loop it replaced
//!    (kept verbatim below as the baseline), in GFLOP/s at the lenet300
//!    layer shapes the L step actually runs — with both dense and
//!    ReLU-sparsified A operands, since the retired kernel skipped
//!    zero-`a` inner loops and hidden-layer activations are ~half zeros.
//!    Full runs assert >= 2x on the dense non-trivial layers; quick
//!    (CI smoke) runs only record the ratios, since shared runners vary
//!    in SIMD width and load.
//! 2. **Dispatch overhead.** Per-call cost of `parallel_map` on the
//!    persistent worker pool vs an equivalent spawn+join scoped dispatch
//!    (the pre-PR-5 implementation, replicated below).
//! 3. **Alloc-free steady state.** Repeated same-shape serial GEMMs
//!    perform zero heap allocations once the thread-local pack buffers
//!    are warm (counting global allocator), and repeated same-shape
//!    *parallel* GEMMs stop growing every worker's pack buffers
//!    (`pack_grow_events_total`, aggregated across the pool).
//! 4. **ISA dispatch.** The runtime-dispatched `Fast` kernel vs the
//!    portable `Exact` kernel (the PR-5 packed kernel's numerics) at a
//!    compute-bound shape.  Full runs on FMA hardware assert >= 2x
//!    GFLOP/s; detected CPU features, the dispatched kernel variants, and
//!    the numerics mode are all recorded in `BENCH_gemm.json`.
//!
//! `LCC_BENCH_QUICK=1` bounds the iteration budget for CI smoke runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use lc::bench::{alloc_counts, write_bench_json, Bencher, CountingAlloc, Record};
use lc::linalg::gemm::{self, AOp, BOp, Isa, Numerics};
use lc::tensor::Matrix;
use lc::util::rng::Xoshiro256;
use lc::util::threadpool::parallel_map;

// counting allocator (shared impl in lc::bench; the attribute must live in
// the binary)
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// --- scalar ikj baseline (the pre-PR-5 kernel, verbatim) -------------------

fn scalar_ikj_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    out.reset(m, n);
    out.data.fill(0.0);
    for i in 0..m {
        let a_row = &a.data[i * k..(i + 1) * k];
        let o_row = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row.iter()) {
                *o += av * bv;
            }
        }
    }
}

// --- spawn+join dispatch baseline (the pre-PR-5 parallel_map) --------------

fn spawn_join_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let out_slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **out_slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    drop(out_slots);
    out.into_iter().map(|v| v.unwrap()).collect()
}

// ---------------------------------------------------------------------------

fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Xoshiro256::new(seed);
    let mut m = Matrix::zeros(rows, cols);
    rng.fill_normal(&mut m.data, 0.0, 1.0);
    m
}

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut records: Vec<Record> = Vec::new();

    let isa = gemm::active_isa();
    println!(
        "cpu features: {} -> dispatch {} (exact: {}, fast: {})",
        gemm::detected_features(),
        isa.name(),
        gemm::kernel_name(isa, Numerics::Exact),
        gemm::kernel_name(isa, Numerics::Fast)
    );
    records.push(Record {
        bench: "gemm_dispatch_metadata".into(),
        fields: vec![
            ("cpu_features".into(), format!("\"{}\"", gemm::detected_features())),
            ("active_isa".into(), format!("\"{}\"", isa.name())),
            ("exact_kernel".into(), format!("\"{}\"", gemm::kernel_name(isa, Numerics::Exact))),
            ("fast_kernel".into(), format!("\"{}\"", gemm::kernel_name(isa, Numerics::Fast))),
            ("numerics_default".into(), format!("\"{}\"", gemm::numerics().name())),
        ],
    });

    // --- packed kernel vs scalar ikj at lenet300 layer shapes --------------
    // (batch 128 forward products; the backward tn/nt products run the same
    // kernel on the same panels, so forward shapes are representative).
    // Hidden-layer A operands are ReLU outputs in the real L step, so those
    // shapes also run with ~50% exact zeros in A — the retired scalar
    // kernel skipped zero-a inner loops, and an all-dense bench would
    // overstate its replacement; the sparse records keep the number honest.
    Bencher::header("GEMM: packed microkernel vs scalar ikj (batch 128)");
    let shapes: &[(usize, usize, usize, bool, bool)] = &[
        (128, 784, 300, false, true), // lenet300 layer 1, dense input pixels
        (128, 300, 100, false, true), // layer 2 upper bound (dense A)
        (128, 300, 100, true, false), // layer 2, ReLU-sparse A (ungated)
        (128, 100, 10, false, false), // logits head: too small to gate
    ];
    for &(m, k, n, relu_a, gated) in shapes {
        let mut a = rand_matrix(m, k, 1);
        if relu_a {
            for v in a.data.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0; // ReLU: ~half the entries become exact zeros
                }
            }
        }
        let w = rand_matrix(k, n, 2);
        let mut out = Matrix::zeros(m, n);
        let gflop = 2.0 * (m * k * n) as f64 / 1e9;
        let tag = if relu_a { " reluA" } else { "" };
        let name = format!("scalar ikj {m}x{k}x{n}{tag}");
        let scalar_ns = b.bench(&name, || scalar_ikj_into(&a, &w, &mut out)).mean_ns;
        let name = format!("packed     {m}x{k}x{n}{tag}");
        let packed_ns = b.bench(&name, || a.matmul_into(&w, &mut out)).mean_ns;
        let scalar_gflops = gflop / (scalar_ns / 1e9);
        let packed_gflops = gflop / (packed_ns / 1e9);
        let speedup = scalar_ns / packed_ns.max(1e-12);
        println!(
            "  {m}x{k}x{n}{tag}: scalar {scalar_gflops:.2} GFLOP/s -> packed \
             {packed_gflops:.2} GFLOP/s ({speedup:.2}x)"
        );
        // full runs gate the acceptance target on the real layer shapes;
        // quick (CI smoke) runs only record the ratio
        if gated && !quick {
            assert!(
                speedup >= 2.0,
                "packed kernel {speedup:.2}x below the 2x target at {m}x{k}x{n}"
            );
        }
        records.push(Record {
            bench: "gemm_packed_vs_scalar".into(),
            fields: vec![
                ("shape".into(), format!("\"{m}x{k}x{n}\"")),
                ("relu_sparse_a".into(), relu_a.to_string()),
                ("scalar_gflops".into(), format!("{scalar_gflops:.3}")),
                ("packed_gflops".into(), format!("{packed_gflops:.3}")),
                ("speedup".into(), format!("{speedup:.3}")),
                ("gated".into(), gated.to_string()),
            ],
        });
    }

    // --- dispatched Fast kernel vs portable Exact (the PR-5 numerics) ------
    // compute-bound shape: k deep enough to amortize packing, several KC
    // panels, output resident in cache.  The gate is the acceptance target
    // "Fast >= 2x the previous packed kernel on FMA hardware"; portable
    // hosts only record the (trivially ~1x) ratio.
    Bencher::header("GEMM: dispatched Fast kernel vs portable Exact (256x1024x512)");
    {
        let (m, k, n) = (256usize, 1024, 512);
        let a = rand_matrix(m, k, 3);
        let w = rand_matrix(k, n, 4);
        let mut out = Matrix::zeros(m, n);
        let gflop = 2.0 * (m * k * n) as f64 / 1e9;
        let exact_ns = b
            .bench("portable exact", || {
                let (pa, pw) = (AOp::N(&a), BOp::N(&w));
                gemm::gemm_forced(pa, pw, &mut out, 1, Isa::Portable, Numerics::Exact)
            })
            .mean_ns;
        let name = format!("{} fast", gemm::kernel_name(isa, Numerics::Fast));
        let fast_ns = b
            .bench(&name, || {
                gemm::gemm_forced(AOp::N(&a), BOp::N(&w), &mut out, 1, isa, Numerics::Fast)
            })
            .mean_ns;
        let exact_gflops = gflop / (exact_ns / 1e9);
        let fast_gflops = gflop / (fast_ns / 1e9);
        let speedup = exact_ns / fast_ns.max(1e-12);
        println!(
            "  {m}x{k}x{n}: portable-exact {exact_gflops:.2} GFLOP/s -> {} \
             {fast_gflops:.2} GFLOP/s ({speedup:.2}x)",
            gemm::kernel_name(isa, Numerics::Fast)
        );
        if isa != Isa::Portable && !quick {
            assert!(
                speedup >= 2.0,
                "dispatched Fast kernel {speedup:.2}x below the 2x target at {m}x{k}x{n}"
            );
        }
        records.push(Record {
            bench: "gemm_fast_vs_portable_exact".into(),
            fields: vec![
                ("shape".into(), format!("\"{m}x{k}x{n}\"")),
                ("fast_kernel".into(), format!("\"{}\"", gemm::kernel_name(isa, Numerics::Fast))),
                ("portable_exact_gflops".into(), format!("{exact_gflops:.3}")),
                ("fast_gflops".into(), format!("{fast_gflops:.3}")),
                ("speedup".into(), format!("{speedup:.3}")),
                ("gated".into(), (isa != Isa::Portable).to_string()),
            ],
        });
    }

    // --- persistent pool vs spawn+join dispatch overhead -------------------
    // four trivial items at four threads: the measurement is pure dispatch
    Bencher::header("dispatch: persistent pool vs spawn+join (4 items, 4 threads)");
    {
        // warm the pool outside the measured region
        parallel_map(4, 4, |i| i);
        let work = || parallel_map(4, 4, |i| std::hint::black_box(i * 2));
        let pool_ns = b.bench("parallel_map (persistent pool)", work).mean_ns;
        let work = || spawn_join_map(4, 4, |i| std::hint::black_box(i * 2));
        let spawn_ns = b.bench("spawn+join scoped dispatch", work).mean_ns;
        let ratio = spawn_ns / pool_ns.max(1e-12);
        println!(
            "  per-call: pool {} vs spawn {} ({ratio:.1}x)",
            lc::bench::fmt_ns(pool_ns),
            lc::bench::fmt_ns(spawn_ns)
        );
        records.push(Record {
            bench: "dispatch_overhead".into(),
            fields: vec![
                ("items".into(), "4".into()),
                ("threads".into(), "4".into()),
                ("pool_ns_per_call".into(), format!("{pool_ns:.1}")),
                ("spawn_ns_per_call".into(), format!("{spawn_ns:.1}")),
                ("spawn_over_pool".into(), format!("{ratio:.3}")),
            ],
        });
    }

    // --- alloc-free steady state (serial path, warm pack buffers) ----------
    {
        let a = rand_matrix(32, 784, 5);
        let w = rand_matrix(784, 300, 6);
        let mut out = Matrix::zeros(32, 300);
        for _ in 0..2 {
            a.matmul_into(&w, &mut out); // warm the pack buffers
        }
        let iters = if quick { 10u64 } else { 50 };
        let (a0, _) = alloc_counts();
        for _ in 0..iters {
            a.matmul_into(&w, &mut out);
            std::hint::black_box(&out);
        }
        let grew = alloc_counts().0 - a0;
        println!("steady-state packed GEMM ({iters} calls): {grew} allocations");
        assert_eq!(grew, 0, "steady-state same-shape GEMM must be allocation-free");
        records.push(Record {
            bench: "gemm_steady_state_allocs".into(),
            fields: vec![
                ("iters".into(), iters.to_string()),
                ("allocs".into(), grew.to_string()),
                ("allocation_free".into(), (grew == 0).to_string()),
            ],
        });
    }

    // --- parallel steady state: pool-wide pack buffers stop growing --------
    // m = 4·ROW_BLOCK, so every row block is full-size and any worker's
    // first touch grows its thread-local A-pack buffer to its final size
    // regardless of which blocks it happens to claim.  Warm generously
    // (work distribution is first-come), then require flatness under the
    // pool-wide counter — the per-thread counter only sees this thread.
    {
        let a = rand_matrix(128, 784, 7);
        let w = rand_matrix(784, 300, 8);
        for _ in 0..20 {
            std::hint::black_box(a.matmul_par(&w, 4));
        }
        let iters = if quick { 10u64 } else { 50 };
        let warm = gemm::pack_grow_events_total();
        for _ in 0..iters {
            std::hint::black_box(a.matmul_par(&w, 4));
        }
        let grew = gemm::pack_grow_events_total() - warm;
        println!("steady-state parallel GEMM ({iters} calls, 4 threads): {grew} pack-grow events");
        if !quick {
            // quick smoke runs share loaded runners where a worker can sit
            // descheduled through the whole warm-up; full runs gate
            assert_eq!(grew, 0, "pool-wide pack buffers must not grow at steady state");
        }
        records.push(Record {
            bench: "gemm_parallel_steady_state_pack_grows".into(),
            fields: vec![
                ("iters".into(), iters.to_string()),
                ("threads".into(), "4".into()),
                ("pack_grow_events".into(), grew.to_string()),
            ],
        });
    }

    write_bench_json("BENCH_gemm.json", &records);
}
