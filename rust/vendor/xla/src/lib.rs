//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The real crate wraps the PJRT C API (CPU plugin) and executes AOT-lowered
//! HLO artifacts.  This stand-in keeps the same API surface so the artifact
//! path in `rust/src/runtime/` compiles, with two behaviors:
//!
//! * [`Literal`] is a **functional** host-side container — the marshalling
//!   helpers (`lit_f32`/`lit_i32`/...) work and stay unit-tested;
//! * everything that would touch a PJRT client ([`PjRtClient::cpu`],
//!   compilation, execution) returns an error, which the runtime dispatch
//!   treats as "PJRT unavailable" and falls back to the native backend.

use std::fmt;
use std::path::Path;

/// Error type for all stubbed operations.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA runtime unavailable in this offline build (stub `xla` crate; \
         the native backend is used instead)"
    ))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Element types a [`Literal`] can hold.
pub trait ArrayElement: Copy {
    const TY: PrimitiveType;
    fn read(lit: &Literal) -> Result<Vec<Self>>;
    fn write(lit: &mut Literal, data: &[Self]) -> Result<()>;
}

/// Host-side typed buffer with a shape — functional in the stub.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    ty: Option<PrimitiveType>,
    dims: Vec<usize>,
    f32s: Vec<f32>,
    i32s: Vec<i32>,
}

impl ArrayElement for f32 {
    const TY: PrimitiveType = PrimitiveType::F32;

    fn read(lit: &Literal) -> Result<Vec<f32>> {
        match lit.ty {
            Some(PrimitiveType::F32) => Ok(lit.f32s.clone()),
            other => Err(XlaError(format!("literal is {other:?}, not F32"))),
        }
    }

    fn write(lit: &mut Literal, data: &[f32]) -> Result<()> {
        match lit.ty {
            Some(PrimitiveType::F32) if lit.f32s.len() == data.len() => {
                lit.f32s.copy_from_slice(data);
                Ok(())
            }
            _ => Err(XlaError("f32 write: shape/type mismatch".into())),
        }
    }
}

impl ArrayElement for i32 {
    const TY: PrimitiveType = PrimitiveType::S32;

    fn read(lit: &Literal) -> Result<Vec<i32>> {
        match lit.ty {
            Some(PrimitiveType::S32) => Ok(lit.i32s.clone()),
            other => Err(XlaError(format!("literal is {other:?}, not S32"))),
        }
    }

    fn write(lit: &mut Literal, data: &[i32]) -> Result<()> {
        match lit.ty {
            Some(PrimitiveType::S32) if lit.i32s.len() == data.len() => {
                lit.i32s.copy_from_slice(data);
                Ok(())
            }
            _ => Err(XlaError("i32 write: shape/type mismatch".into())),
        }
    }
}

impl Literal {
    /// Zero-initialized literal of the given element type and shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let mut lit = Literal { ty: Some(ty), dims: dims.to_vec(), ..Default::default() };
        match ty {
            PrimitiveType::F32 => lit.f32s = vec![0.0; n],
            PrimitiveType::S32 => lit.i32s = vec![0; n],
        }
        lit
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { ty: Some(PrimitiveType::F32), dims: Vec::new(), f32s: vec![v], ..Default::default() }
    }

    pub fn element_count(&self) -> usize {
        self.f32s.len().max(self.i32s.len())
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    pub fn copy_raw_from<T: ArrayElement>(&mut self, data: &[T]) -> Result<()> {
        T::write(self, data)
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::read(self)
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        T::read(self)?
            .first()
            .copied()
            .ok_or_else(|| XlaError("empty literal".into()))
    }

    /// Untuple — stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module handle (stub: never constructible from disk).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {}", path.display())))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 2]);
        lit.copy_raw_from(&[1.0f32, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert_eq!(Literal::scalar(2.5).get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn client_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope")).is_err());
    }
}
