//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the API surface the `lc` crate uses: an opaque
//! [`Error`] carrying a message chain, [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros.  `{e}` prints the outermost message, `{e:#}` the full
//! `a: b: c` chain (matching anyhow's alternate formatting).

use std::fmt;

/// An error message chain (outermost context first).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what makes this blanket conversion coherent (same trick as the real
// anyhow crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, cause: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to fallible
/// values, converting them into [`Error`] chains.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        io.context("outer")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(1u32).context("missing").unwrap(), 1);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert!(format!("{}", f(5).unwrap_err()).contains("x != 5"));
        assert_eq!(format!("{}", f(3).unwrap_err()), "three");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
