//! Toolchain probe for the GEMM kernel family.
//!
//! The AVX-512 intrinsics (`core::arch::x86_64::_mm512_*`) and
//! `#[target_feature(enable = "avx512f")]` are only stable since Rust 1.89,
//! but this crate must keep building on older stable toolchains.  The build
//! script parses `rustc --version` and emits the `lcc_avx512` cfg when the
//! compiler is new enough; `linalg/gemm.rs` gates its 16-lane microkernel
//! variants on that cfg and falls back to the AVX2/portable kernels
//! otherwise.  Runtime CPU detection is a separate, orthogonal gate.

use std::process::Command;

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let minor = rustc_minor_version().unwrap_or(0);
    // `cargo:rustc-check-cfg` itself needs Cargo/rustc >= 1.80 (where the
    // `unexpected_cfgs` lint it silences also first appears).
    if minor >= 80 {
        println!("cargo:rustc-check-cfg=cfg(lcc_avx512)");
    }
    if minor >= 89 {
        println!("cargo:rustc-cfg=lcc_avx512");
    }
}

/// Minor version of the active `rustc` ("rustc 1.89.0 (...)" -> 89).
fn rustc_minor_version() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    let version = text.split_whitespace().nth(1)?;
    version.split('.').nth(1)?.parse().ok()
}
