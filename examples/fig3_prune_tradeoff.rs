//! Reproduces **Fig. 3 (right)**: ℓ0-constrained pruning with LC (thick
//! curves in the paper) vs magnitude pruning + retraining (thin curves),
//! sweeping the kept-weights fraction κ.
//!
//! Paper claim (shape): LC tracks or beats magnitude+retrain everywhere
//! and degrades far more gracefully at extreme sparsity; the horizontal
//! dashed line is the uncompressed reference error.
//!
//! ```text
//! cargo run --release --example fig3_prune_tradeoff [-- --fast]
//! ```

use lc::compress::prune::ConstraintL0;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::harness::{scaled_quant_config, Env, Scale};
use lc::models::lookup;
use lc::report::{ascii_plot, pct, Series, Table};

fn tasks_for(kappa: usize) -> TaskSet {
    TaskSet::new(vec![TaskSpec {
        name: format!("prune_{kappa}"),
        layers: vec![0, 1],
        view: View::Vector,
        compression: Box::new(ConstraintL0 { kappa }),
    }])
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast {
        Scale { n_train: 2048, n_test: 1024, reference_epochs: 6, ..Default::default() }
    } else {
        Scale { reference_epochs: 16, ..Default::default() }
    };
    let threads = scale.threads;
    let mut env = Env::new(scale)?;
    let spec = lookup("mlp-small").map_err(anyhow::Error::msg)?;
    let n = spec.n_weights();

    let reference = env.reference(&spec)?;
    let ref_test = env.evaluate(&reference, true)?;
    println!(
        "reference {}: test_err={} (the paper's dashed line)",
        spec.name,
        pct(ref_test.error)
    );

    let pcts: &[f64] = if fast { &[0.05, 0.20] } else { &[0.01, 0.02, 0.05, 0.10, 0.20] };
    let retrain_epochs = if fast { 6 } else { 16 };

    let mut lc_pts = Vec::new();
    let mut mag_pts = Vec::new();
    let mut table = Table::new(&[
        "kept weights",
        "kappa",
        "LC test err",
        "magnitude+retrain test err",
        "reference",
    ]);

    for &p in pcts {
        let kappa = ((n as f64) * p) as usize;
        let mut cfg = scaled_quant_config(threads);
        cfg.lr.lr0 = 0.1; // the paper's pruning lr
        if fast {
            cfg.mu.steps = 8;
            cfg.mu.growth = 2.3; // same endpoint as the 20-step schedule
        }
        let reference = env.reference(&spec)?;
        let lc_out = env.run_lc(&spec, tasks_for(kappa), cfg, reference)?;

        // magnitude pruning + retrain = compress_retrain with the l0 task
        let reference = env.reference(&spec)?;
        let mag_out =
            env.run_retrain(&spec, &tasks_for(kappa), reference, retrain_epochs, 0.02, 1e-3)?;

        lc::info!(
            "keep {:.0}%: LC={} mag+retrain={}",
            p * 100.0,
            pct(lc_out.final_test.error),
            pct(mag_out.test.error)
        );
        table.row(&[
            format!("{:.0}%", p * 100.0),
            kappa.to_string(),
            pct(lc_out.final_test.error),
            pct(mag_out.test.error),
            pct(ref_test.error),
        ]);
        lc_pts.push((p * 100.0, lc_out.final_test.error * 100.0));
        mag_pts.push((p * 100.0, mag_out.test.error * 100.0));
    }

    println!("\nFig. 3 (right) reproduced — l0 pruning trade-off on SynthDigits:");
    println!("{}", table.render());
    let plot = ascii_plot(
        "test error vs kept-weight fraction (left = sparser)",
        "kept weights %",
        "test error %",
        &[
            Series { label: "LC l0-constraint".into(), marker: 'o', points: lc_pts.clone() },
            Series { label: "magnitude prune+retrain".into(), marker: 'x', points: mag_pts.clone() },
        ],
        60,
        16,
        true,
    );
    println!("{plot}");

    let dominated = lc_pts
        .iter()
        .zip(mag_pts.iter())
        .filter(|((_, a), (_, b))| a <= b)
        .count();
    println!(
        "LC at-or-below magnitude+retrain at {dominated}/{} sparsity levels \
         (paper: LC wins, gap widest at extreme sparsity)",
        lc_pts.len()
    );
    Ok(())
}
