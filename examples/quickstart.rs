//! Quickstart: compress a small MLP with 2-value adaptive quantization
//! using the LC algorithm, in ~a minute on a laptop CPU.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Mirrors the paper's Listing 1: build the tasks, hand the L step to the
//! runtime, call run().

use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::harness::{scaled_quant_config, Env, Scale};
use lc::models::lookup;
use lc::report::pct;

fn main() -> anyhow::Result<()> {
    let scale = Scale { n_train: 4096, n_test: 1024, reference_epochs: 10, ..Default::default() };
    let mut env = Env::new(scale)?;
    let spec = lookup("mlp-small").map_err(anyhow::Error::msg)?;

    // 1. reference model (cached across runs)
    let reference = env.reference(&spec)?;
    let ref_test = env.evaluate(&reference, true)?;
    println!("reference {}: test_err={}", spec.name, pct(ref_test.error));

    // 2. compression tasks — the paper's mix-and-match structure:
    //    quantize ALL weights with a single learned 2-value codebook
    let tasks = TaskSet::new(vec![TaskSpec {
        name: "quantize_everything".into(),
        layers: vec![0, 1],
        view: View::Vector,
        compression: Box::new(AdaptiveQuant::new(2)),
    }]);

    // 3. run LC
    let mut cfg = scaled_quant_config(4);
    cfg.mu.steps = 12;
    cfg.quiet = false;
    let out = env.run_lc(&spec, tasks, cfg, reference)?;

    println!();
    println!("LC-compressed model:");
    println!("  test error        {}", pct(out.final_test.error));
    println!("  train error       {}", pct(out.final_train.error));
    println!("  storage ratio     {:.1}x smaller", out.metrics.ratio());
    println!("  wall time         {:.1}s over {} L steps", out.wall_secs, out.records.len());
    println!("  monitor           {} violations", out.monitor.violations.len());
    if let lc::compress::Theta::Quantized { codebook, .. } = &out.thetas[0] {
        println!("  learned codebook  {codebook:?}");
    }
    Ok(())
}
