//! Reproduces **Fig. 4**: the error–FLOPs–#params space spanned by
//! automatic rank selection.  For each network, sweeping λ traces a curve
//! from the dense reference (bottom-right) up and left; smaller λ keeps
//! more rank (more FLOPs, lower error).
//!
//! Paper claims (shape): each net's λ-sweep spans a frontier; bigger nets
//! start lower-right; the frontier is monotone (more FLOPs → less error,
//! up to noise).
//!
//! ```text
//! cargo run --release --example fig4_rank_selection [-- --fast]
//! ```

use lc::compress::lowrank::{RankCost, RankSelection};
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::harness::{scaled_lowrank_config, Env, Scale};
use lc::models::lookup;
use lc::report::{ascii_plot, pct, Series, Table};

fn tasks_for(nl: usize, lambda: f64) -> TaskSet {
    TaskSet::new(
        (0..nl)
            .map(|l| TaskSpec {
                name: format!("rs{l}"),
                layers: vec![l],
                view: View::Matrix,
                compression: Box::new(RankSelection {
                    lambda,
                    cost: RankCost::Flops,
                    max_rank: 0,
                }),
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast {
        Scale { n_train: 2048, n_test: 1024, reference_epochs: 6, ..Default::default() }
    } else {
        Scale { reference_epochs: 16, ..Default::default() }
    };
    let threads = scale.threads;
    let mut env = Env::new(scale)?;

    let models: &[&str] = if fast { &["mlp-small"] } else { &["mlp-small", "lenet300"] };
    let lambdas: &[f64] = if fast { &[1e-6, 1e-4] } else { &[1e-7, 1e-6, 1e-5, 1e-4] };

    let mut all_series = Vec::new();
    let mut table = Table::new(&[
        "model",
        "lambda",
        "test err",
        "MFLOPs",
        "params",
        "FLOPs ratio",
        "per-layer ranks",
    ]);
    let markers = ['o', 'd', '*'];

    for (mi, model) in models.iter().enumerate() {
        let spec = lookup(model).map_err(anyhow::Error::msg)?;
        let reference = env.reference(&spec)?;
        let ref_test = env.evaluate(&reference, true)?;
        let mut pts = vec![(
            spec.flops_dense() as f64 / 1e6,
            ref_test.error * 100.0,
        )];
        table.row(&[
            model.to_string(),
            "0 (reference)".into(),
            pct(ref_test.error),
            format!("{:.3}", spec.flops_dense() as f64 / 1e6),
            spec.n_params().to_string(),
            "1.0x".into(),
            "dense".into(),
        ]);

        for &lambda in lambdas {
            let mut cfg = scaled_lowrank_config(threads);
            if fast {
                cfg.mu.steps = 8;
                cfg.mu.growth = 2.6; // same endpoint as the 20-step schedule
            }
            let reference = env.reference(&spec)?;
            let out = env.run_lc(&spec, tasks_for(spec.n_layers(), lambda), cfg, reference)?;
            let ranks: Vec<usize> = out
                .thetas
                .iter()
                .map(|t| match t {
                    lc::compress::Theta::LowRank { s, .. } => {
                        s.iter().filter(|&&x| x != 0.0).count()
                    }
                    _ => 0,
                })
                .collect();
            lc::info!(
                "{model} lambda={lambda:.0e}: err={} flops_ratio={:.1} ranks={ranks:?}",
                pct(out.final_test.error),
                out.metrics.flops_ratio()
            );
            table.row(&[
                model.to_string(),
                format!("{lambda:.0e}"),
                pct(out.final_test.error),
                format!("{:.3}", out.metrics.flops as f64 / 1e6),
                out.metrics.params.to_string(),
                format!("{:.1}x", out.metrics.flops_ratio()),
                format!("{ranks:?}"),
            ]);
            pts.push((out.metrics.flops as f64 / 1e6, out.final_test.error * 100.0));
        }
        all_series.push(Series {
            label: format!("{model} (lambda sweep)"),
            marker: markers[mi % markers.len()],
            points: pts,
        });
    }

    println!("\nFig. 4 reproduced — error vs inference FLOPs via rank selection:");
    println!("{}", table.render());
    let plot = ascii_plot(
        "error-compression space (paper Fig. 4): each curve is one net's lambda sweep",
        "inference MFLOPs",
        "test error %",
        &all_series,
        64,
        18,
        true,
    );
    println!("{plot}");
    println!(
        "paper shape check: curves start at the dense reference (right) and move\n\
         left/up as lambda grows; larger nets sit further right."
    );
    Ok(())
}
