//! Reproduces **Fig. 3 (left)**: the quantization error–compression
//! trade-off — LC adaptive quantization (thick blue curve in the paper)
//! vs the quantize→retrain approach of Deep-Compression lineage (thin red
//! curve) across codebook sizes.
//!
//! Paper claim to reproduce (shape, not absolute numbers): the LC curve
//! dominates quantize→retrain, with the gap widening at aggressive
//! compression (small codebooks).
//!
//! ```text
//! cargo run --release --example fig3_quant_tradeoff [-- --fast]
//! ```

use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::harness::{scaled_quant_config, Env, Scale};
use lc::models::lookup;
use lc::report::{ascii_plot, pct, Series, Table};

/// Per-layer codebooks, as in the paper's quantization experiments (a
/// joint codebook across layers with different weight scales is far more
/// destructive and is not what Fig. 3 measures).
fn tasks_for(k: usize) -> TaskSet {
    TaskSet::new(
        (0..2)
            .map(|l| TaskSpec {
                name: format!("quant_k{k}_l{l}"),
                layers: vec![l],
                view: View::Vector,
                compression: Box::new(AdaptiveQuant::new(k)),
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast {
        Scale { n_train: 2048, n_test: 1024, reference_epochs: 6, ..Default::default() }
    } else {
        Scale { reference_epochs: 16, ..Default::default() }
    };
    let threads = scale.threads;
    let mut env = Env::new(scale)?;
    let spec = lookup("mlp-small").map_err(anyhow::Error::msg)?;

    let reference = env.reference(&spec)?;
    let ref_test = env.evaluate(&reference, true)?;
    println!("reference {}: test_err={}", spec.name, pct(ref_test.error));

    let ks: &[usize] = if fast { &[2, 16] } else { &[2, 4, 16, 64] };
    let retrain_epochs = if fast { 6 } else { 16 };

    let mut lc_pts = Vec::new();
    let mut rt_pts = Vec::new();
    let mut table = Table::new(&[
        "codebook k",
        "storage ratio",
        "LC test err",
        "quant+retrain test err",
        "DC test err",
    ]);

    for &k in ks {
        let mut cfg = scaled_quant_config(threads);
        if fast {
            cfg.mu.steps = 8;
            cfg.mu.growth = 2.3; // same endpoint as the 20-step schedule
        }
        let reference = env.reference(&spec)?;
        let lc_out = env.run_lc(&spec, tasks_for(k), cfg, reference)?;

        let reference = env.reference(&spec)?;
        let rt_out =
            env.run_retrain(&spec, &tasks_for(k), reference, retrain_epochs, 0.02, 1e-3)?;

        let reference = env.reference(&spec)?;
        let dc_out = env.run_dc(&spec, &tasks_for(k), &reference, 1e-3)?;

        let ratio = lc_out.metrics.ratio();
        lc::info!(
            "k={k}: ratio={ratio:.1}x LC={} retrain={} DC={}",
            pct(lc_out.final_test.error),
            pct(rt_out.test.error),
            pct(dc_out.test.error)
        );
        table.row(&[
            k.to_string(),
            format!("{ratio:.1}x"),
            pct(lc_out.final_test.error),
            pct(rt_out.test.error),
            pct(dc_out.test.error),
        ]);
        lc_pts.push((ratio, lc_out.final_test.error * 100.0));
        rt_pts.push((ratio, rt_out.test.error * 100.0));
    }

    println!("\nFig. 3 (left) reproduced — quantization trade-off on SynthDigits:");
    println!("{}", table.render());
    let plot = ascii_plot(
        "test error vs compression ratio (higher ratio = smaller model)",
        "storage compression ratio",
        "test error %",
        &[
            Series { label: "LC (this work)".into(), marker: 'o', points: lc_pts.clone() },
            Series { label: "quantize+retrain".into(), marker: 'x', points: rt_pts.clone() },
        ],
        60,
        16,
        true,
    );
    println!("{plot}");

    // the paper's qualitative claim: LC dominates at every ratio
    let dominated = lc_pts
        .iter()
        .zip(rt_pts.iter())
        .filter(|((_, lc_err), (_, rt_err))| lc_err <= rt_err)
        .count();
    println!(
        "LC at-or-below quantize+retrain at {dominated}/{} codebook sizes \
         (paper: LC dominates, gap widest at small k)",
        lc_pts.len()
    );
    Ok(())
}
