//! Reproduces **Table 2** of the paper: the LeNet300 showcase — seven
//! compression schemes (plus the reference) on the same pretrained net,
//! each expressed as nothing but a different compression-tasks structure.
//!
//! ```text
//! cargo run --release --example table2_showcase            # full table
//! cargo run --release --example table2_showcase -- --fast  # smoke scale
//! ```
//!
//! Also measures the paper's headline runtime claim: LC wall-clock vs
//! reference-training wall-clock (abstract: "comparable").

use std::time::Instant;

use lc::compress::additive::AdditiveCombination;
use lc::compress::lowrank::{LowRank, RankCost, RankSelection};
use lc::compress::prune::ConstraintL0;
use lc::compress::quantize::AdaptiveQuant;
use lc::compress::task::{TaskSet, TaskSpec};
use lc::compress::view::View;
use lc::compress::Compression;
use lc::harness::{scaled_lowrank_config, scaled_quant_config, Env, Scale};
use lc::models::lookup;
use lc::report::{pct, Table};

fn v(name: &str, layers: Vec<usize>, c: Box<dyn Compression>) -> TaskSpec {
    TaskSpec { name: name.into(), layers, view: View::Vector, compression: c }
}

fn m(name: &str, layer: usize, c: Box<dyn Compression>) -> TaskSpec {
    TaskSpec { name: name.into(), layers: vec![layer], view: View::Matrix, compression: c }
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast {
        Scale { n_train: 2048, n_test: 1024, reference_epochs: 6, ..Default::default() }
    } else {
        Scale::default()
    };
    let threads = scale.threads;
    let mut env = Env::new(scale)?;
    let spec = lookup("lenet300").map_err(anyhow::Error::msg)?;
    let n = spec.n_weights(); // 266,200 like the paper
    let kappa5 = n / 20; // 13,310 = 5%
    let kappa1 = n / 100; // 2,662 = 1%

    // reference (timed for the runtime-ratio claim)
    let t_ref = Instant::now();
    let reference = env.reference(&spec)?;
    let ref_wall = t_ref.elapsed().as_secs_f64();
    let ref_train = env.evaluate(&reference, false)?;
    let ref_test = env.evaluate(&reference, true)?;

    let mut cfg_q = scaled_quant_config(threads);
    let mut cfg_lr = scaled_lowrank_config(threads);
    if fast {
        cfg_q.mu.steps = 8;
        cfg_q.mu.growth = 2.3; // same mu endpoint as the 20-step schedule
        cfg_lr.mu.steps = 8;
        cfg_lr.mu.growth = 2.6;
    }

    // Table 2 rows: (label, tasks, low-rank-schedule?, paper test err)
    let rows: Vec<(&str, Vec<TaskSpec>, bool, &str)> = vec![
        (
            "quantize all layers (k=2 each)",
            vec![
                v("q1", vec![0], Box::new(AdaptiveQuant::new(2))),
                v("q2", vec![1], Box::new(AdaptiveQuant::new(2))),
                v("q3", vec![2], Box::new(AdaptiveQuant::new(2))),
            ],
            false,
            "2.56%",
        ),
        (
            "quantize first and third layers",
            vec![
                v("q1", vec![0], Box::new(AdaptiveQuant::new(2))),
                v("q3", vec![2], Box::new(AdaptiveQuant::new(2))),
            ],
            false,
            "2.26%",
        ),
        (
            "prune all but 5%",
            vec![v("p", vec![0, 1, 2], Box::new(ConstraintL0 { kappa: kappa5 }))],
            false,
            "2.18%",
        ),
        (
            "single codebook quant + additive prune 1%",
            vec![v(
                "mix",
                vec![0, 1, 2],
                Box::new(AdditiveCombination::new(vec![
                    Box::new(ConstraintL0 { kappa: kappa1 }),
                    Box::new(AdaptiveQuant::new(2)),
                ])),
            )],
            false,
            "2.17%",
        ),
        (
            "prune L1 / low-rank L2 (r=10) / quantize L3",
            vec![
                v("p1", vec![0], Box::new(ConstraintL0 { kappa: 5000 })),
                m("lr2", 1, Box::new(LowRank { target_rank: 10 })),
                v("q3", vec![2], Box::new(AdaptiveQuant::new(2))),
            ],
            true,
            "2.51%",
        ),
        (
            "rank selection (lambda=1e-6)",
            vec![
                m("r1", 0, Box::new(RankSelection { lambda: 1e-6, cost: RankCost::Storage, max_rank: 0 })),
                m("r2", 1, Box::new(RankSelection { lambda: 1e-6, cost: RankCost::Storage, max_rank: 0 })),
                m("r3", 2, Box::new(RankSelection { lambda: 1e-6, cost: RankCost::Storage, max_rank: 0 })),
            ],
            true,
            "1.90%",
        ),
    ];

    let mut table = Table::new(&[
        "compression",
        "train err",
        "test err",
        "paper test err",
        "storage ratio",
        "LC/ref time",
    ]);
    table.row(&[
        "no compression (reference)".into(),
        pct(ref_train.error),
        pct(ref_test.error),
        "2.13%".into(),
        "1.0x".into(),
        "-".into(),
    ]);

    for (label, tasks, lowrank, paper_err) in rows {
        let cfg = if lowrank { cfg_lr.clone() } else { cfg_q.clone() };
        let reference = env.reference(&spec)?;
        let out = env.run_lc(&spec, TaskSet::new(tasks), cfg, reference)?;
        lc::info!(
            "{label}: test={} ratio={:.1} wall={:.0}s violations={}",
            pct(out.final_test.error),
            out.metrics.ratio(),
            out.wall_secs,
            out.monitor.violations.len()
        );
        table.row(&[
            label.into(),
            pct(out.final_train.error),
            pct(out.final_test.error),
            paper_err.into(),
            format!("{:.1}x", out.metrics.ratio()),
            format!("{:.1}x", out.wall_secs / ref_wall.max(1e-9)),
        ]);
    }

    println!("\nTable 2 (paper) reproduced on SynthDigits @ laptop scale:");
    println!("{}", table.render());
    println!(
        "reference training wall-clock: {ref_wall:.1}s; paper's claim: LC runtime is\n\
         comparable to reference training (see LC/ref column)."
    );
    Ok(())
}
