"""AOT path tests: HLO lowering succeeds, text parses, manifest matches the
model registry, and the lowered train step is numerically faithful to the
eager train step (same inputs -> same outputs, via jax CPU execution)."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_model_variants_match_expected_registry():
    # must mirror rust/src/models/mod.rs::registry()
    assert set(M.MODEL_VARIANTS) == {"mlp-small", "lenet300", "lenet300-wide"}
    widths, batch, eval_batch = M.MODEL_VARIANTS["lenet300"]
    assert widths == [784, 300, 100, 10]
    assert batch == 128 and eval_batch == 512


def _entry_param_count(hlo_text):
    """Number of parameters of the ENTRY computation.  Sub-computations
    (reduction bodies etc.) have their own parameter(i) instructions, so we
    count only within the ENTRY block (from the 'ENTRY' header line to its
    closing brace)."""
    lines = hlo_text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    count = 0
    for line in lines[start + 1 :]:
        if line.strip() == "}":
            break
        if " parameter(" in line:
            count += 1
    return count


def test_lowered_hlo_text_nonempty_and_parseable_header():
    train_txt, eval_txt = aot.lower_variant("mlp-small")
    assert "HloModule" in train_txt
    assert "HloModule" in eval_txt
    # the train module must take the documented number of parameters:
    # 2*2*nl params+momenta + x + y + 2*nl deltas/lambdas + mu + lr
    nl = M.n_layers(M.MODEL_VARIANTS["mlp-small"][0])
    assert _entry_param_count(train_txt) == 4 * nl + 2 + 2 * nl + 2
    assert _entry_param_count(eval_txt) == 2 * nl + 2


def test_quant_lowering_has_expected_parameters():
    txt = aot.lower_quant(4)
    assert "HloModule" in txt
    assert _entry_param_count(txt) == 2  # (w, codebook)


def test_train_entry_flat_signature_roundtrip():
    """The flat AOT entry must agree with the structured train_step."""
    widths, batch, _ = M.MODEL_VARIANTS["mlp-small"]
    nl = M.n_layers(widths)
    rng = np.random.default_rng(0)

    def mk(shape):
        return jnp.asarray(rng.normal(size=shape, scale=0.1), dtype=jnp.float32)

    params, momenta = [], []
    for l in range(nl):
        params += [mk((widths[l], widths[l + 1])), mk((widths[l + 1],))]
        momenta += [mk((widths[l], widths[l + 1])), mk((widths[l + 1],))]
    x = mk((batch, widths[0]))
    y = jnp.asarray(rng.integers(0, widths[-1], size=(batch,)), dtype=jnp.int32)
    deltas = [mk((widths[l], widths[l + 1])) for l in range(nl)]
    lambdas = [mk((widths[l], widths[l + 1])) for l in range(nl)]
    mu = jnp.asarray([0.5] * nl, dtype=jnp.float32)
    lr = jnp.float32(0.01)

    entry = M.make_train_entry(widths)
    flat_out = entry(*(params + momenta + [x, y] + deltas + lambdas + [mu, lr]))
    sp, sm, sl = M.train_step(params, momenta, x, y, deltas, lambdas, mu, lr, widths)

    assert len(flat_out) == 4 * nl + 1
    for a, b in zip(flat_out[: 2 * nl], sp):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    for a, b in zip(flat_out[2 * nl : 4 * nl], sm):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    np.testing.assert_allclose(flat_out[-1], sl, rtol=1e-6)


def test_manifest_written(tmp_path):
    """End-to-end aot.main with a single variant writes a valid manifest."""
    out = tmp_path / "arts"
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(out), "--only", "mlp-small", "--skip-quant"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = (out / "manifest.txt").read_text()
    assert manifest.startswith("version 1")
    assert "model mlp-small widths 784,100,10 batch 128 eval_batch 512" in manifest
    assert (out / "mlp-small_train.hlo.txt").exists()
    assert (out / "mlp-small_eval.hlo.txt").exists()
