"""L2 model math vs hand-rolled jnp oracles: forward, loss, penalty, SGD."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

WIDTHS = [12, 8, 5]  # tiny 2-layer MLP for fast exact checks


def make_params(widths, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    flat = []
    for l in range(M.n_layers(widths)):
        flat.append(jnp.asarray(rng.normal(size=(widths[l], widths[l + 1]), scale=scale), dtype=jnp.float32))
        flat.append(jnp.asarray(rng.normal(size=(widths[l + 1],), scale=scale), dtype=jnp.float32))
    return flat


def forward_oracle(flat, x, widths):
    h = x
    nl = M.n_layers(widths)
    for l in range(nl):
        h = h @ flat[2 * l] + flat[2 * l + 1][None, :]
        if l < nl - 1:
            h = jnp.maximum(h, 0.0)
    return h


def test_forward_matches_oracle():
    flat = make_params(WIDTHS, 0)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(9, 12)), dtype=jnp.float32)
    np.testing.assert_allclose(
        M.forward(flat, x, WIDTHS), forward_oracle(flat, x, WIDTHS), rtol=1e-5, atol=1e-5
    )


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 1.0, 0.0], [0.0, 0.0, 0.0]], dtype=jnp.float32)
    y = jnp.asarray([0, 2], dtype=jnp.int32)
    # manual: -log softmax[y]
    p0 = np.exp(2.0) / (np.exp(2.0) + np.exp(1.0) + 1.0)
    want = (-np.log(p0) - np.log(1.0 / 3.0)) / 2.0
    np.testing.assert_allclose(M.cross_entropy(logits, y), want, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(mu=st.floats(0.0, 10.0), seed=st.integers(0, 1000))
def test_penalty_matches_quadratic_form(mu, seed):
    """For lambda=0 the expanded penalty equals mu/2 ||W - D||^2 exactly."""
    flat = make_params(WIDTHS, seed)
    rng = np.random.default_rng(seed + 1)
    deltas = [
        jnp.asarray(rng.normal(size=flat[2 * l].shape), dtype=jnp.float32)
        for l in range(M.n_layers(WIDTHS))
    ]
    lambdas = [jnp.zeros_like(d) for d in deltas]
    mu_vec = jnp.full((M.n_layers(WIDTHS),), mu, dtype=jnp.float32)
    got = M.lc_penalty(flat, deltas, lambdas, mu_vec, WIDTHS)
    want = sum(
        0.5 * mu * float(jnp.sum((flat[2 * l] - deltas[l]) ** 2))
        for l in range(M.n_layers(WIDTHS))
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_penalty_gradient_is_mu_diff_minus_lambda():
    """d/dW [mu/2||W-D||^2 - <lam, W-D>] = mu (W - D) - lam."""
    flat = make_params(WIDTHS, 3)
    nl = M.n_layers(WIDTHS)
    rng = np.random.default_rng(4)
    deltas = [jnp.asarray(rng.normal(size=flat[2 * l].shape), dtype=jnp.float32) for l in range(nl)]
    lambdas = [jnp.asarray(rng.normal(size=flat[2 * l].shape), dtype=jnp.float32) for l in range(nl)]
    mu = jnp.full((M.n_layers(WIDTHS),), 2.5, dtype=jnp.float32)

    g = jax.grad(lambda fp: M.lc_penalty(fp, deltas, lambdas, mu, WIDTHS))(flat)
    for l in range(nl):
        want = mu[l] * (flat[2 * l] - deltas[l]) - lambdas[l]
        np.testing.assert_allclose(g[2 * l], want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(g[2 * l + 1], jnp.zeros_like(g[2 * l + 1]))


def test_train_step_is_nesterov_sgd():
    """With mu=0, lam=0 the update must equal hand-computed PyTorch-Nesterov."""
    flat = make_params(WIDTHS, 5)
    moms = [jnp.full_like(p, 0.1) for p in flat]
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 12)), dtype=jnp.float32)
    y = jnp.asarray(rng.integers(0, 5, size=(4,)), dtype=jnp.int32)
    nl = M.n_layers(WIDTHS)
    deltas = [jnp.zeros_like(flat[2 * l]) for l in range(nl)]
    lambdas = [jnp.zeros_like(flat[2 * l]) for l in range(nl)]
    mu, lr = jnp.zeros((nl,), dtype=jnp.float32), jnp.float32(0.05)

    new_p, new_m, loss = M.train_step(flat, moms, x, y, deltas, lambdas, mu, lr, WIDTHS)

    grads = jax.grad(
        lambda fp: M.penalized_loss(fp, x, y, deltas, lambdas, mu, WIDTHS)
    )(flat)
    for p, v, g, p2, v2 in zip(flat, moms, grads, new_p, new_m):
        v_want = M.MOMENTUM * v + g
        p_want = p - lr * (g + M.MOMENTUM * v_want)
        np.testing.assert_allclose(v2, v_want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(p2, p_want, rtol=1e-5, atol=1e-6)
    assert float(loss) > 0.0


def test_train_step_reduces_loss_over_iterations():
    """A few steps of SGD on a fixed batch must reduce the penalized loss."""
    widths = [6, 16, 3]
    flat = make_params(widths, 7, scale=0.3)
    moms = [jnp.zeros_like(p) for p in flat]
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(32, 6)), dtype=jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, size=(32,)), dtype=jnp.int32)
    nl = M.n_layers(widths)
    deltas = [jnp.zeros_like(flat[2 * l]) for l in range(nl)]
    lambdas = [jnp.zeros_like(flat[2 * l]) for l in range(nl)]
    mu, lr = jnp.full((nl,), 0.01, dtype=jnp.float32), jnp.float32(0.1)

    losses = []
    for _ in range(8):
        flat, moms, loss = M.train_step(flat, moms, x, y, deltas, lambdas, mu, lr, widths)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_eval_step_counts():
    widths = [4, 3]
    w = jnp.eye(4, 3, dtype=jnp.float32) * 10.0
    b = jnp.zeros((3,), dtype=jnp.float32)
    x = jnp.asarray(np.eye(4, dtype=np.float32))[:3]  # rows select classes 0,1,2
    y = jnp.asarray([0, 1, 0], dtype=jnp.int32)  # third is wrong on purpose
    loss_sum, correct = M.eval_step([w, b], x, y, widths)
    assert int(correct) == 2
    assert float(loss_sum) > 0.0


def test_arg_shapes_roundtrip():
    widths, batch = [784, 300, 100, 10], 128
    shapes = M.train_arg_shapes(widths, batch)
    nl = M.n_layers(widths)
    assert len(shapes) == 2 * (2 * nl) + 2 + 2 * nl + 2
    # first param is W1
    assert shapes[0].shape == (784, 300)
    # x and y
    assert shapes[4 * nl].shape == (batch, 784)
    assert shapes[4 * nl + 1].shape == (batch,)
    # trailing mu vector + lr scalar
    assert shapes[-1].shape == () and shapes[-2].shape == (nl,)
    ev = M.eval_arg_shapes(widths, 512)
    assert ev[-2].shape == (512, 784)
