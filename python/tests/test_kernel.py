"""Kernel-vs-oracle tests: the CORE correctness signal for L1.

hypothesis sweeps shapes and values; every Pallas kernel must match its
pure-jnp oracle (kernels/ref.py) to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.linear import fused_linear, matmul
from compile.kernels.quant_assign import quant_assign

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 784, 300),  # lenet300 layer 1, exact batch
        (128, 300, 100),
        (128, 100, 10),
        (1, 1, 1),
        (129, 257, 127),  # all dims straddle tile boundaries
        (256, 128, 128),  # exactly tile-aligned
    ],
)
def test_matmul_shapes(m, k, n):
    x, w = rand((m, k), 7), rand((k, n), 8)
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused linear forward
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, relu, seed):
    x, w, b = rand((m, k), seed), rand((k, n), seed + 1), rand((n,), seed + 2)
    got = fused_linear(x, w, b, relu)
    want = ref.fused_linear_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_linear_relu_clamps():
    x = jnp.asarray([[1.0, -1.0]], dtype=jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), dtype=jnp.float32)
    out = fused_linear(x, w, b, True)
    np.testing.assert_allclose(out, [[1.0, 0.0]])


# ---------------------------------------------------------------------------
# fused linear backward (custom VJP) vs autodiff-of-oracle
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_grad_matches_ref(m, k, n, relu, seed):
    x, w, b = rand((m, k), seed), rand((k, n), seed + 1), rand((n,), seed + 2)

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, relu) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, relu) ** 2)

    g_kernel = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quant_assign (k-means E-step + sufficient statistics)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(1, 4),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_assign_matches_ref(nb, k, seed):
    block = 128
    w = rand((nb * block,), seed)
    c = rand((k,), seed + 1)
    a, d, s, n = quant_assign(w, c, block_n=block)
    a_r, d_r, s_r, n_r = ref.quant_assign_ref(w, c)
    np.testing.assert_array_equal(a, a_r)
    np.testing.assert_allclose(d, d_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s, s_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(n, n_r, rtol=0, atol=0)


def test_quant_assign_exact_centers():
    # weights exactly on centers -> zero distortion, exact counts
    c = jnp.asarray([-1.0, 0.0, 1.0], dtype=jnp.float32)
    w = jnp.tile(c, 128)  # 384 weights
    a, d, s, n = quant_assign(w, c, block_n=128)
    assert float(d) == 0.0
    np.testing.assert_allclose(n, [128.0, 128.0, 128.0])
    np.testing.assert_allclose(s, [-128.0, 0.0, 128.0])


def test_quant_assign_singleton_codebook():
    w = rand((256,), 3)
    c = jnp.asarray([0.25], dtype=jnp.float32)
    a, d, s, n = quant_assign(w, c, block_n=128)
    assert int(a.sum()) == 0
    np.testing.assert_allclose(d, jnp.sum((w - 0.25) ** 2), rtol=1e-5)
