"""L2: the LC algorithm's L step as a JAX compute graph.

The model family is a fully-connected classifier (LeNet300-style MLP); its
dense layers are the L1 Pallas ``fused_linear`` kernel, so the whole train
step lowers into one HLO module built from Pallas-derived ops.

The train step implements exactly the paper's L step (Fig. 2, Listing 2):

    min_w  L(w) + mu/2 * || w - Delta(Theta) - lambda/mu ||^2

optimized by SGD with Nesterov momentum (the PyTorch convention used in the
paper's Listing 2: v <- m*v + g; w <- w - lr*(g + m*v)).  The penalty is
applied to *weight matrices only* (biases train freely, as in the reference
library, which compresses `lX.weight` tensors).

The penalty inputs Delta(Theta) and lambda enter the graph as constants of
the optimization (the C step owns them), matching the LC separation: the L
step has the same form for every compression type.

Conventions shared with the Rust runtime (rust/src/runtime/):
  * parameters are a flat list [W1, b1, ..., WL, bL], Wl is f32[in, out]
  * momenta mirror the parameter list
  * labels are i32[B]; inputs are f32[B, in_dim]
  * train_step input order:
      params..., momenta..., x, y, deltas (one per W), lambdas (one per W),
      mu (f32[L] -- per weight matrix, 0 disables the penalty for layers
      not covered by any compression task), lr (f32[])
  * train_step output order: new_params..., new_momenta..., loss (f32[])
  * eval_step inputs: params..., x, y; outputs: (loss_sum f32[], correct i32[])
"""

import jax
import jax.numpy as jnp

from compile.kernels.linear import fused_linear

MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Model family registry (mirrored by rust/src/models/registry.rs).
# ---------------------------------------------------------------------------

MODEL_VARIANTS = {
    # name: (layer widths incl. input/output, train batch, eval batch)
    "mlp-small": ([784, 100, 10], 128, 512),
    "lenet300": ([784, 300, 100, 10], 128, 512),
    "lenet300-wide": ([784, 500, 300, 10], 128, 512),
}


def n_layers(widths):
    return len(widths) - 1


def unflatten_params(flat, widths):
    """[W1, b1, W2, b2, ...] -> [(W1, b1), ...] with shape checks."""
    layers = []
    for l in range(n_layers(widths)):
        w, b = flat[2 * l], flat[2 * l + 1]
        assert w.shape == (widths[l], widths[l + 1]), (w.shape, widths, l)
        assert b.shape == (widths[l + 1],), (b.shape, widths, l)
        layers.append((w, b))
    return layers


def forward(flat_params, x, widths):
    """MLP forward: ReLU hidden layers, identity logits head."""
    layers = unflatten_params(flat_params, widths)
    h = x
    for l, (w, b) in enumerate(layers):
        relu = l < len(layers) - 1
        h = fused_linear(h, w, b, relu)
    return h


def cross_entropy(logits, y):
    """Mean softmax cross-entropy; y is i32[B] class labels."""
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.mean(logz - picked)


def lc_penalty(flat_params, deltas, lambdas, mu, widths):
    """sum_l mu_l/2 * || W_l - Delta_l - lambda_l/mu_l ||^2 over weights.

    ``mu`` is a per-weight-matrix vector f32[L]: layers not covered by any
    compression task get mu_l = 0 (no penalty).  Written in the numerically-
    safe expanded form so that mu_l = 0 (including the first, direct-
    compression step) does not divide by zero:
        mu_l/2 * ||W - D||^2 - <lambda_l, W - D>  (+ const)
    which has the same gradient in W as the paper's quadratic.
    """
    pen = 0.0
    for l in range(n_layers(widths)):
        w = flat_params[2 * l]
        diff = (w - deltas[l]).reshape(-1)
        pen = pen + 0.5 * mu[l] * jnp.vdot(diff, diff) - jnp.vdot(
            lambdas[l].reshape(-1), diff
        )
    return pen


def penalized_loss(flat_params, x, y, deltas, lambdas, mu, widths):
    return cross_entropy(forward(flat_params, x, widths), y) + lc_penalty(
        flat_params, deltas, lambdas, mu, widths
    )


def train_step(flat_params, momenta, x, y, deltas, lambdas, mu, lr, widths):
    """One SGD-with-Nesterov-momentum step on the penalized L-step objective.

    Returns (new_params, new_momenta, loss) where loss is the penalized
    objective *before* the update (used by the coordinator's monitor).
    """
    loss, grads = jax.value_and_grad(penalized_loss)(
        flat_params, x, y, deltas, lambdas, mu, widths
    )
    new_params, new_momenta = [], []
    for p, v, g in zip(flat_params, momenta, grads):
        v2 = MOMENTUM * v + g
        p2 = p - lr * (g + MOMENTUM * v2)
        new_params.append(p2)
        new_momenta.append(v2)
    return new_params, new_momenta, loss


def eval_step(flat_params, x, y, widths):
    """Sum of per-example CE loss and count of correct predictions."""
    logits = forward(flat_params, x, widths)
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    loss_sum = jnp.sum(logz - picked)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.int32))
    return loss_sum, correct


# ---------------------------------------------------------------------------
# Flat-signature entrypoints for AOT lowering (aot.py).  PJRT gives us a flat
# list of parameters, so the lowered functions take/return flat tuples.
# ---------------------------------------------------------------------------


def make_train_entry(widths):
    nl = n_layers(widths)

    def entry(*args):
        i = 0
        params = list(args[i : i + 2 * nl]); i += 2 * nl
        momenta = list(args[i : i + 2 * nl]); i += 2 * nl
        x = args[i]; i += 1
        y = args[i]; i += 1
        deltas = list(args[i : i + nl]); i += nl
        lambdas = list(args[i : i + nl]); i += nl
        mu = args[i]; i += 1
        lr = args[i]; i += 1
        assert i == len(args)
        new_p, new_m, loss = train_step(
            params, momenta, x, y, deltas, lambdas, mu, lr, widths
        )
        return tuple(new_p) + tuple(new_m) + (loss,)

    return entry


def make_eval_entry(widths):
    nl = n_layers(widths)

    def entry(*args):
        params = list(args[: 2 * nl])
        x, y = args[2 * nl], args[2 * nl + 1]
        loss_sum, correct = eval_step(params, x, y, widths)
        return (loss_sum, correct)

    return entry


def param_shapes(widths):
    """[(shape, dtype)] for the flat param list [W1, b1, ...]."""
    shapes = []
    for l in range(n_layers(widths)):
        shapes.append(((widths[l], widths[l + 1]), jnp.float32))
        shapes.append(((widths[l + 1],), jnp.float32))
    return shapes


def train_arg_shapes(widths, batch):
    """ShapeDtypeStructs in the exact train_step input order."""
    nl = n_layers(widths)
    f32, i32 = jnp.float32, jnp.int32
    ps = [jax.ShapeDtypeStruct(s, d) for s, d in param_shapes(widths)]
    shapes = list(ps) + list(ps)  # params then momenta
    shapes.append(jax.ShapeDtypeStruct((batch, widths[0]), f32))
    shapes.append(jax.ShapeDtypeStruct((batch,), i32))
    for l in range(nl):
        shapes.append(jax.ShapeDtypeStruct((widths[l], widths[l + 1]), f32))
    for l in range(nl):
        shapes.append(jax.ShapeDtypeStruct((widths[l], widths[l + 1]), f32))
    shapes.append(jax.ShapeDtypeStruct((nl,), f32))  # mu (per weight matrix)
    shapes.append(jax.ShapeDtypeStruct((), f32))  # lr
    return shapes


def eval_arg_shapes(widths, batch):
    f32, i32 = jnp.float32, jnp.int32
    shapes = [jax.ShapeDtypeStruct(s, d) for s, d in param_shapes(widths)]
    shapes.append(jax.ShapeDtypeStruct((batch, widths[0]), f32))
    shapes.append(jax.ShapeDtypeStruct((batch,), i32))
    return shapes
