"""L1 Pallas kernels: tiled matmul and the fused linear layer (matmul+bias+ReLU).

These are the compute hot-spots of the LC algorithm's L step (the model
forward/backward).  The kernels are written TPU-style:

  * the matmul is tiled into (bm, bn, bk) blocks sized for the MXU systolic
    array (128x128 where the layer allows it) with the K-reduction expressed
    as grid revisiting of the same output block -- the canonical Pallas
    accumulation pattern;
  * bias-add and ReLU are fused into the final K-step so the activation
    never round-trips through HBM;
  * BlockSpecs express the HBM->VMEM schedule; VMEM footprint per grid step
    is bm*bk + bk*bn + bm*bn floats (see DESIGN.md section "Perf").

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target and
TPU performance is estimated analytically (DESIGN.md).

The backward pass is provided via ``jax.custom_vjp`` built from the same
matmul kernel (pallas_call has no automatic transpose rule), so the whole
train step lowers into one HLO module of Pallas-derived ops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles.  For the small showcase layers the wrapper clamps
# these to the (padded) problem size, so tiny layers run as a single block.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_kernel(x_ref, w_ref, o_ref, *, nsteps_k: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps_k: int, relu: bool):
    """Matmul with bias-add (+ optional ReLU) fused into the last K-step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps_k - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _pick_tiles(m: int, n: int, k: int, bm: int, bn: int, bk: int):
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    return bm, bn, bk


def matmul(x: jax.Array, w: jax.Array, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK) -> jax.Array:
    """Tiled Pallas matmul ``x @ w`` for f32 2-D operands (pads internally)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = _pick_tiles(m, n, k, bm, bn, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _fused_linear_fwd_impl(x, w, b, relu: bool, bm, bn, bk):
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _pick_tiles(m, n, k, bm, bn, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b.reshape(1, -1), ((0, 0), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_fused_linear_kernel, nsteps_k=grid[2], relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, relu: bool = False):
    """``relu?(x @ w + b)`` as a single fused Pallas kernel, differentiable.

    The VJP is hand-written from the same tiled matmul kernel:
      dx = dy' @ w.T,  dw = x.T @ dy',  db = sum(dy'), with dy' = dy * mask.
    """
    return _fused_linear_fwd_impl(x, w, b, relu, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK)


def _fused_linear_fwd(x, w, b, relu: bool):
    y = _fused_linear_fwd_impl(x, w, b, relu, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK)
    # Residuals: inputs plus the activation mask (y > 0 iff pre-act > 0 when
    # relu; for the identity head the mask is unused).
    return y, (x, w, y)


def _fused_linear_bwd(relu: bool, res, dy):
    x, w, y = res
    if relu:
        dy = dy * (y > 0.0).astype(dy.dtype)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
