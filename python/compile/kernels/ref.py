"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel in this package must
match its oracle to float32 tolerance across the hypothesis shape/value
sweeps in python/tests/.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.matmul(x, w)


def fused_linear_ref(x, w, b, relu: bool = False):
    y = jnp.matmul(x, w) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def quant_assign_ref(w, c):
    d2 = (w[:, None] - c[None, :]) ** 2
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist = jnp.min(d2, axis=1).sum()
    k = c.shape[0]
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    sums = (w[:, None] * onehot).sum(axis=0)
    counts = onehot.sum(axis=0)
    return assign, dist, sums, counts
