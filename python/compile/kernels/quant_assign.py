"""L1 Pallas kernel for the adaptive-quantization C step (k-means E-step).

Given a flat weight vector ``w`` (padded to a block multiple) and a codebook
``c`` of K centers, one pass computes, entirely in VMEM per block:

  * ``assign``  -- nearest-center index per weight (the k-means assignment),
  * ``dist``    -- total quadratic distortion  sum_i min_k (w_i - c_k)^2,
  * ``sums``    -- per-center sums   sum_{i: a_i=k} w_i,
  * ``counts``  -- per-center counts |{i: a_i=k}|.

``sums``/``counts`` are exactly the sufficient statistics of the Lloyd
M-step, so the Rust coordinator can run full k-means by alternating this
artifact with a trivial ``c_k = sums_k / counts_k`` host update.  The
reduction outputs use the grid-revisiting accumulation pattern (their
index_map is constant), which the sequential interpret-mode grid executes
in-order.

TPU mapping (DESIGN.md section Hardware-Adaptation): one grid step holds a
(1, bn) weight tile plus the whole (1, K) codebook in VMEM (K <= 64), and
the (bn, K) distance matrix is a VPU elementwise job; the one-hot matmul
producing ``sums`` feeds the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 4096  # weights per grid step


def _kernel(w_ref, c_ref, a_ref, d_ref, s_ref, n_ref, *, k: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    w = w_ref[...]  # (1, bn)
    c = c_ref[...]  # (1, k)
    d2 = (w[0][:, None] - c[0][None, :]) ** 2  # (bn, k)
    a = jnp.argmin(d2, axis=1)  # (bn,)
    a_ref[...] = a[None, :].astype(jnp.int32)
    d_ref[...] += jnp.min(d2, axis=1).sum()[None, None]
    onehot = (a[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)  # (bn, k)
    s_ref[...] += jnp.dot(w, onehot, preferred_element_type=jnp.float32)
    n_ref[...] += jnp.sum(onehot, axis=0)[None, :]


def quant_assign(w: jax.Array, c: jax.Array, *, block_n: int = BLOCK_N):
    """Assignment + distortion + Lloyd sufficient statistics, one fused pass.

    Args:
      w: f32[N] flat weights; N must be a multiple of ``block_n`` (the AOT
         wrapper and the Rust caller pad with ``c[0]`` and correct counts).
      c: f32[K] codebook.
    Returns:
      (assign i32[N], dist f32[], sums f32[K], counts f32[K]).
    """
    (n,) = w.shape
    (k,) = c.shape
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    wb = w.reshape(nb, block_n)
    cb = c.reshape(1, k)
    out_shapes = (
        jax.ShapeDtypeStruct((nb, block_n), jnp.int32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, k), jnp.float32),
        jax.ShapeDtypeStruct((1, k), jnp.float32),
    )
    assign, dist, sums, counts = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(wb, cb)
    return assign.reshape(n), dist.reshape(()), sums.reshape(k), counts.reshape(k)
