"""AOT compile path: lower the L2/L1 graphs to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust coordinator loads the emitted
``artifacts/*.hlo.txt`` files through the PJRT C API and never touches
Python again.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly.

Emitted artifacts (see artifacts/manifest.txt, parsed by
rust/src/runtime/manifest.rs):
  * per model variant: ``<name>_train.hlo.txt`` (one SGD step on the
    penalized L-step objective) and ``<name>_eval.hlo.txt``;
  * the quantization C-step kernel ``quant_assign_k<K>.hlo.txt`` for a
    fixed weight-buffer size, used by the Rust k-means when a task fits.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only lenet300]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.quant_assign import quant_assign, BLOCK_N

# Fixed flat-weight buffer size for the quantization C-step artifact.  The
# largest single compression task in the experiment suite is the whole
# lenet300-wide net viewed as a vector (~545k weights), so 2^20 covers all
# tasks; the Rust caller pads with c[0] and corrects counts/distortion.
QUANT_N = 1 << 20
QUANT_KS = (2, 4, 16, 64)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str):
    widths, batch, eval_batch = M.MODEL_VARIANTS[name]
    train = jax.jit(M.make_train_entry(widths)).lower(
        *M.train_arg_shapes(widths, batch)
    )
    evalf = jax.jit(M.make_eval_entry(widths)).lower(
        *M.eval_arg_shapes(widths, eval_batch)
    )
    return to_hlo_text(train), to_hlo_text(evalf)


def lower_quant(k: int):
    def entry(w, c):
        assign, dist, sums, counts = quant_assign(w, c)
        return (assign, dist, sums, counts)

    spec_w = jax.ShapeDtypeStruct((QUANT_N,), jnp.float32)
    spec_c = jax.ShapeDtypeStruct((k,), jnp.float32)
    lowered = jax.jit(entry).lower(spec_w, spec_c)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single model variant")
    ap.add_argument("--skip-quant", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = ["version 1"]
    variants = [args.only] if args.only else list(M.MODEL_VARIANTS)
    for name in variants:
        widths, batch, eval_batch = M.MODEL_VARIANTS[name]
        train_txt, eval_txt = lower_variant(name)
        tf, ef = f"{name}_train.hlo.txt", f"{name}_eval.hlo.txt"
        for fn, txt in ((tf, train_txt), (ef, eval_txt)):
            with open(os.path.join(args.out_dir, fn), "w") as f:
                f.write(txt)
        manifest.append(
            "model {} widths {} batch {} eval_batch {} train {} eval {}".format(
                name, ",".join(map(str, widths)), batch, eval_batch, tf, ef
            )
        )
        print(f"[aot] {name}: train={len(train_txt)}B eval={len(eval_txt)}B")

    if not args.skip_quant:
        for k in QUANT_KS:
            txt = lower_quant(k)
            fn = f"quant_assign_k{k}.hlo.txt"
            with open(os.path.join(args.out_dir, fn), "w") as f:
                f.write(txt)
            manifest.append(
                f"quant n {QUANT_N} block {BLOCK_N} k {k} file {fn}"
            )
            print(f"[aot] quant_assign k={k}: {len(txt)}B")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] wrote manifest with {len(manifest) - 1} artifacts")


if __name__ == "__main__":
    main()
